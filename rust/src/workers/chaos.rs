//! Artifact-free chaos/failover harness: a scripted leader driving the
//! REAL scheduler and REAL attention workers (native backend) over either
//! transport, with fault injection on the links — the end-to-end proof of
//! the fault-tolerance story that CI can run without PJRT artifacts.
//!
//! # The pseudo-model and why its outputs are bit-exact
//!
//! The real leader's model slices need AOT artifacts, so this harness
//! substitutes a deterministic pseudo-model chosen to make recovery
//! verifiable to the bit:
//!
//! * **K is constant across positions** for each (layer, head). Every
//!   attention score in a row is then equal, so the online softmax's
//!   weights are *exactly* 1.0 (`exp(0)`), and the attention output is
//!   the mean of the V rows — accumulated in position order by both the
//!   decode kernel (`fold_block`) and the prefill kernel (`fold_one`).
//!   The same context therefore produces bit-identical attention output
//!   whether it arrived via decode steps or via the preempt-replay
//!   re-prefill after a worker death.
//! * **V encodes the content**: each V row is a function of (token,
//!   position, layer, head, dim), so the attention output — and the next
//!   token derived from it — checksums the *entire KV history* on the
//!   workers. A lost, stale, or corrupted KV row changes the output
//!   stream; matching the fault-free golden run proves the rebuilt cache
//!   is byte-equivalent.
//! * **Next token = FNV fold of every layer's attention output row**, mod
//!   a small vocab — a real recurrence (each token depends on all prior
//!   tokens through the KV cache) covering every layer's stored V.
//!
//! The leader loop mirrors `workers::leader`: real [`Scheduler`]
//! (admission, chunked prefill, packed decode groups, retirement), the
//! same [`HealthPolicy`] deadline/retry death detection, and the same
//! preempt-replay-rebuild recovery. What it cannot exercise is the PJRT
//! model math — covered by the artifact-gated `e2e_pipeline` failover
//! tests.

use std::time::{Duration, Instant};

use crate::coordinator::failover::{
    DeathCause, HealthPolicy, HealthTracker, MembershipPolicy, Verdict, WorkerDeath,
};
use crate::kernels::AttnBackendKind;
use crate::kvcache::{head_ranges, KvDtype, ShardRange};
use crate::metrics::{KvCacheStats, ServeMetrics};
use crate::net::{inproc, tcp, DeadTransport, FaultPlan, FaultTransport, Transport, TransportKind};
use crate::netsim::stack::{FHBN, LINE_RATE_400G};
use crate::obs;
use crate::runtime::host::HostTensor;
use crate::scheduler::{
    AdmissionKind, DecodeRow, GroupMode, KvBudget, KvOccupancy, RequestId, SchedCfg, Scheduler,
};

use super::attn_worker::{run_attn_worker, AttnWorkerCfg, ModelGeom};
use super::leader::dial_worker;
use super::messages::WireMsg;

/// Pseudo-model vocabulary (next tokens are hashes mod this).
pub const VOCAB: i32 = 97;
const LAYERS: usize = 2;
const HEADS: usize = 8;
const KV_HEADS: usize = 4;
const HEAD_DIM: usize = 8;
const MAX_SEQ: usize = 64;
/// Prefill chunk size (small, so kills can land between chunks).
const PREFILL_CHUNK: usize = 8;
const HASH_INIT: u32 = 0x811C_9DC5;

/// Chaos session configuration.
#[derive(Debug, Clone)]
pub struct ChaosCfg {
    pub transport: TransportKind,
    /// Attention workers: any width `1..=4` (contiguous head-range
    /// shards; the 4 KV heads need not divide evenly).
    pub workers: usize,
    /// Concurrent requests (deterministic synthetic prompts).
    pub requests: usize,
    /// Tokens to generate per request.
    pub gen_tokens: usize,
    /// Physical cache slots.
    pub slots: usize,
    /// Fault schedule for the leader-side links (`None` = golden run).
    pub fault_plan: Option<FaultPlan>,
    pub health: HealthPolicy,
    /// Recover from worker deaths (preempt-replay-rebuild). Off: the
    /// first death aborts the session with a typed [`ChaosFailure`].
    pub auto_recover: bool,
    /// Respawn replacements on death (`--no-respawn` clears it). Cleared,
    /// a death **degrades** the pool to the survivors (epoch-fenced
    /// reshard, bit-identical output) down to the `min_workers` floor.
    pub allow_respawn: bool,
    /// Smallest pool width degradation may leave (`--min-workers`);
    /// refusing to go below it aborts typed with zero leaks.
    pub min_workers: usize,
    /// Adopt one extra worker at this step boundary (`--adopt`): the
    /// scripted W→W+1 scale-up reshard.
    pub adopt_at_step: Option<usize>,
    /// Deterministic link kills: at step boundary `.0`, sever worker
    /// `.1`'s link (the leader's `inject_worker_death`, scripted). Unlike
    /// `fault_plan` message-count triggers, these land *between* steps —
    /// the degrade-ladder tests use them for exact W=4→3→2 scripts.
    pub kill_at: Vec<(usize, usize)>,
    /// Remote cluster mode: `HOST:PORT` of a standalone `lamina-attn`
    /// process per worker index (including respawn/adopt targets — a
    /// respawn re-dials the same address). `None` spawns in-process
    /// threads per `transport` as before.
    pub worker_addrs: Option<Vec<String>>,
    /// Test hook invoked at each step boundary with the step number —
    /// e2e tests use it to SIGKILL a subprocess at an exact point in the
    /// session. Plain fn pointer so the config stays `Clone + Debug`.
    pub on_step: Option<fn(usize)>,
}

impl Default for ChaosCfg {
    fn default() -> ChaosCfg {
        ChaosCfg {
            transport: TransportKind::Inproc,
            workers: 2,
            requests: 3,
            gen_tokens: 8,
            slots: 4,
            fault_plan: None,
            // tight deadlines: native steps are sub-ms, and chaos tests
            // should detect hangs quickly
            health: HealthPolicy {
                recv_deadline: Duration::from_millis(400),
                recv_retries: 1,
                backoff: 2.0,
            },
            auto_recover: true,
            allow_respawn: true,
            min_workers: 1,
            adopt_at_step: None,
            kill_at: Vec::new(),
            worker_addrs: None,
            on_step: None,
        }
    }
}

/// What a completed chaos session produced.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// Generated tokens per request, in submission order.
    pub outputs: Vec<Vec<i32>>,
    pub worker_deaths: u64,
    pub recoveries: u64,
    pub tokens_replayed: u64,
    /// Engine iterations run.
    pub steps: usize,
    /// KV blocks still mapped after the session drained (leak check —
    /// must be 0).
    pub leaked_blocks: usize,
    /// Graceful degradations (reshards to W−1 survivors).
    pub degrades: u64,
    /// Scale-up adoptions (reshards to W+1 members).
    pub adoptions: u64,
    /// Pool width at drain (differs from the starting width after
    /// degrades/adoptions).
    pub final_workers: usize,
}

/// Typed session abort: the death that ended it plus the post-cleanup
/// leak count over the surviving workers (must be 0 — a failed session
/// must not strand KV reservations).
#[derive(Debug)]
pub struct ChaosFailure {
    pub death: WorkerDeath,
    pub leaked_blocks: usize,
}

impl std::fmt::Display for ChaosFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "chaos session aborted: {} ({} blocks leaked)", self.death, self.leaked_blocks)
    }
}

impl std::error::Error for ChaosFailure {}

/// Deterministic synthetic prompt for request `r` (3–5 tokens).
pub fn prompt_for(r: usize) -> Vec<i32> {
    (0..3 + r % 3).map(|i| ((r * 13 + i * 5 + 2) % VOCAB as usize) as i32).collect()
}

// ---- the pseudo-model ------------------------------------------------------

/// Constant K per (layer, head): every score equal → softmax weights
/// exactly 1.0 → attention output is the position-ordered mean of V rows.
fn k_val(layer: usize, head: usize, d: usize) -> f32 {
    (((layer * KV_HEADS + head) * HEAD_DIM + d) % 23) as f32 / 16.0
}

/// V encodes (token, position) — the content the KV cache must preserve
/// across worker death and replay. Multiples of 1/8 keep sums exact.
fn v_val(token: i32, pos: usize, layer: usize, head: usize, d: usize) -> f32 {
    let mix = token as i64 * 31
        + pos as i64 * 17
        + (layer * KV_HEADS + head) as i64 * 7
        + d as i64;
    (mix.rem_euclid(113)) as f32 / 8.0
}

/// Q is irrelevant to the output under constant K (all scores equal
/// regardless), but keep it deterministic and position-dependent anyway.
fn q_val(token: i32, pos: usize, layer: usize, head: usize, d: usize) -> f32 {
    let mix = token as i64 * 5 + pos as i64 * 3 + (layer * HEADS + head) as i64 + d as i64;
    (mix.rem_euclid(29)) as f32 / 16.0
}

/// Build `[rows, heads, HEAD_DIM]` from a per-(row, head, dim) function.
fn build(rows: usize, heads: usize, f: impl Fn(usize, usize, usize) -> f32) -> HostTensor {
    let mut data = vec![0.0f32; rows * heads * HEAD_DIM];
    for r in 0..rows {
        for h in 0..heads {
            for d in 0..HEAD_DIM {
                data[(r * heads + h) * HEAD_DIM + d] = f(r, h, d);
            }
        }
    }
    HostTensor::f32(vec![rows, heads, HEAD_DIM], data)
}

/// Head-range slice of `[rows, H, hd]` (the leader's shard split).
fn slice_heads(t: &HostTensor, h0: usize, n: usize) -> HostTensor {
    let shape = t.shape();
    let (b, h, hd) = (shape[0], shape[1], shape[2]);
    if h0 == 0 && n == h {
        return t.clone();
    }
    let src = t.as_f32();
    let mut out = vec![0.0f32; b * n * hd];
    for bi in 0..b {
        out[bi * n * hd..][..n * hd].copy_from_slice(&src[(bi * h + h0) * hd..][..n * hd]);
    }
    HostTensor::f32(vec![b, n, hd], out)
}

/// FNV-1a-style fold of a row's f32 bit patterns.
fn fold_row(mut h: u32, row: &[f32]) -> u32 {
    for &x in row {
        h = (h ^ x.to_bits()).wrapping_mul(0x0100_0193);
    }
    h
}

// ---- worker spawning -------------------------------------------------------

struct Peer {
    link: Box<dyn Transport>,
    thread: Option<std::thread::JoinHandle<()>>,
    health: HealthTracker,
}

fn spawn_peer(cfg: &ChaosCfg, idx: usize, respawn: bool) -> Result<Peer, String> {
    let wcfg = AttnWorkerCfg {
        // deliberately nonexistent: the native backend must not need it
        artifacts_dir: std::path::PathBuf::from("artifacts-not-needed"),
        shard: idx,
        n_shards: cfg.workers,
        slots: cfg.slots,
        kv_block_size: 4,
        kv_dtype: KvDtype::F32,
        backend: AttnBackendKind::Native,
        geom: Some(ModelGeom {
            layers: LAYERS,
            kv_heads: KV_HEADS,
            head_dim: HEAD_DIM,
            max_seq: MAX_SEQ,
        }),
        trust_welcome: false,
    };
    let name = if respawn { format!("chaos-attn-{idx}-r") } else { format!("chaos-attn-{idx}") };
    let builder = std::thread::Builder::new().name(name);
    let (mut link, thread): (Box<dyn Transport>, Option<std::thread::JoinHandle<()>>) =
        match (&cfg.worker_addrs, cfg.transport) {
            // remote cluster: dial a standalone lamina-attn process with the
            // same bounded-retry ladder the real leader uses; no thread to
            // join (the subprocess owns its own lifetime)
            (Some(addrs), _) => {
                let spec = addrs
                    .get(idx)
                    .ok_or_else(|| format!("no address for worker {idx} (got {})", addrs.len()))?;
                let addr = crate::net::Addr::parse(spec).map_err(|e| e.to_string())?;
                let l = dial_worker(&addr, &cfg.health)?;
                (Box::new(l), None)
            }
            (None, TransportKind::Inproc) => {
                let (l, w) = inproc::pair(&FHBN, LINE_RATE_400G, 0.0);
                let t =
                    builder.spawn(move || run_attn_worker(wcfg, w)).map_err(|e| e.to_string())?;
                (Box::new(l), Some(t))
            }
            (None, TransportKind::Tcp) => {
                let (l, w) = tcp::pair().map_err(|e| e.to_string())?;
                let t =
                    builder.spawn(move || run_attn_worker(wcfg, w)).map_err(|e| e.to_string())?;
                (Box::new(l), Some(t))
            }
        };
    // same contract as the real leader: respawns are never fault-wrapped
    if !respawn {
        if let Some(plan) = &cfg.fault_plan {
            if plan.is_armed() && plan.applies_to(idx) {
                link = Box::new(FaultTransport::new(link, plan.clone(), idx as u64));
            }
        }
    }
    Ok(Peer { link, thread, health: HealthTracker::default() })
}

// ---- the scripted leader ---------------------------------------------------

struct Chaos<'c> {
    cfg: &'c ChaosCfg,
    peers: Vec<Peer>,
    sched: Scheduler,
    metrics: ServeMetrics,
    deaths: u64,
    recoveries: u64,
    tokens_replayed: u64,
    /// Per-peer contiguous KV-head ranges (mirrors the leader's plan).
    plan: Vec<ShardRange>,
    /// Membership epoch (mirrors the leader's; bumped on every reshard).
    epoch: u64,
    degrades: u64,
    adoptions: u64,
}

impl<'c> Chaos<'c> {
    fn new(cfg: &'c ChaosCfg) -> Result<Chaos<'c>, String> {
        assert!(
            cfg.workers >= 1 && cfg.workers <= KV_HEADS,
            "workers must be 1..={KV_HEADS}"
        );
        let mut peers = Vec::new();
        for w in 0..cfg.workers {
            peers.push(spawn_peer(cfg, w, false)?);
        }
        let plan = head_ranges(KV_HEADS, cfg.workers).map_err(|e| e.to_string())?;
        let sched = Scheduler::new(
            SchedCfg {
                max_context: MAX_SEQ - 1,
                total_slots: cfg.slots,
                group_slots: cfg.slots,
                grouping: GroupMode::Packed,
                use_prefill: true,
                kv_block_size: 4,
                block_bytes: 0,
                budget: KvBudget::Unlimited,
                overcommit: false,
            },
            AdmissionKind::Fifo.build(),
        );
        let mut chaos = Chaos {
            cfg,
            peers,
            sched,
            metrics: ServeMetrics::new(),
            deaths: 0,
            recoveries: 0,
            tokens_replayed: 0,
            plan,
            epoch: 1,
            degrades: 0,
            adoptions: 0,
        };
        // membership handshake before any data-plane traffic (the real
        // leader's start() contract)
        for wi in 0..chaos.peers.len() {
            chaos.handshake_hello(wi).map_err(|d| d.to_string())?;
            let msg = chaos.welcome_msg(wi);
            chaos.send_to(wi, msg).map_err(|d| d.to_string())?;
        }
        Ok(chaos)
    }

    /// Leader side of the membership handshake (the real leader's
    /// `handshake_hello`, scripted): the link's first frame must be a
    /// version-compatible `Hello`.
    fn handshake_hello(&mut self, wi: usize) -> Result<(), WorkerDeath> {
        let t0 = Instant::now();
        match self.recv_worker(wi)? {
            WireMsg::Hello { codec_version, shard: _ } => {
                if codec_version != crate::net::codec::FORMAT_VERSION as u32 {
                    return Err(self.declare_dead(
                        wi,
                        DeathCause::Protocol(format!(
                            "worker speaks codec v{codec_version}, leader v{}",
                            crate::net::codec::FORMAT_VERSION
                        )),
                        t0,
                    ));
                }
                Ok(())
            }
            other => Err(self.declare_dead(
                wi,
                DeathCause::Protocol(format!("expected Hello, got {other:?}")),
                t0,
            )),
        }
    }

    /// Peer `wi`'s `Welcome` from the current plan and epoch.
    fn welcome_msg(&self, wi: usize) -> WireMsg {
        let r = self.plan[wi];
        WireMsg::Welcome {
            epoch: self.epoch,
            kv_start: r.start as u32,
            kv_count: r.count as u32,
            slots: self.cfg.slots as u32,
            kv_block_size: 4,
            layers: LAYERS as u32,
            head_dim: HEAD_DIM as u32,
            max_seq: MAX_SEQ as u32,
        }
    }

    /// Sever peer `wi`'s link *now* (the leader's `inject_worker_death`,
    /// scripted): counters preserved, the worker thread observes the
    /// disconnect and exits, the next wire op surfaces a typed death.
    fn inject_kill(&mut self, wi: usize) {
        let p = &mut self.peers[wi];
        let dead = DeadTransport::new(p.link.kind(), p.link.stats());
        p.link = Box::new(dead);
    }

    /// Same contract as the leader's `declare_dead`: record detection
    /// metrics + timeline marker, build the typed death.
    fn declare_dead(&mut self, wi: usize, cause: DeathCause, since: Instant) -> WorkerDeath {
        crate::metrics::note_worker_death(since.elapsed().as_secs_f64());
        self.deaths += 1;
        obs::instant(
            "failover",
            "worker-dead",
            vec![
                ("worker", obs::ArgVal::I(wi as i64)),
                ("cause", obs::ArgVal::S(cause.name().to_string())),
            ],
        );
        WorkerDeath { worker: wi, cause }
    }

    /// Deadline/retry-governed receive (the leader's ladder, verbatim).
    fn recv_worker(&mut self, wi: usize) -> Result<WireMsg, WorkerDeath> {
        let t0 = Instant::now();
        loop {
            let attempt = self.peers[wi].health.strikes();
            let deadline = self.cfg.health.attempt_deadline(attempt);
            match self.peers[wi].link.recv_timeout(deadline) {
                Ok(Some(WireMsg::WorkerError { msg })) => {
                    return Err(self.declare_dead(wi, DeathCause::Protocol(msg), t0));
                }
                Ok(Some(msg)) => {
                    self.peers[wi].health.on_alive();
                    return Ok(msg);
                }
                Ok(None) => match self.peers[wi].health.on_timeout(&self.cfg.health) {
                    Verdict::Retry(_) => crate::metrics::note_failover_retry(),
                    Verdict::Dead => return Err(self.declare_dead(wi, DeathCause::Hang, t0)),
                },
                Err(e) => {
                    return Err(self.declare_dead(wi, DeathCause::of_transport(&e), t0));
                }
            }
        }
    }

    fn send_to(&mut self, wi: usize, msg: WireMsg) -> Result<(), WorkerDeath> {
        match self.peers[wi].link.send(msg) {
            Ok(()) => Ok(()),
            Err(e) => Err(self.declare_dead(wi, DeathCause::of_transport(&e), Instant::now())),
        }
    }

    /// Receive one attention shard per worker and interleave them back
    /// into `[rows, HEADS, HEAD_DIM]` (flat).
    fn recv_attn(&mut self, layer: usize, rows: usize) -> Result<Vec<f32>, WorkerDeath> {
        let w = self.peers.len();
        let group = HEADS / KV_HEADS;
        let mut out = vec![0.0f32; rows * HEADS * HEAD_DIM];
        for wi in 0..w {
            match self.recv_worker(wi)? {
                WireMsg::AttnOut { layer: l, out: shard } if l == layer => {
                    let qr = self.plan[wi].q_range(group);
                    let sd = shard.as_f32();
                    for b in 0..rows {
                        let dst = (b * HEADS + qr.start) * HEAD_DIM;
                        let src = b * qr.count * HEAD_DIM;
                        out[dst..dst + qr.count * HEAD_DIM]
                            .copy_from_slice(&sd[src..src + qr.count * HEAD_DIM]);
                    }
                }
                other => {
                    return Err(self.declare_dead(
                        wi,
                        DeathCause::Protocol(format!("unexpected reply {other:?}")),
                        Instant::now(),
                    ));
                }
            }
        }
        Ok(out)
    }

    fn send_retirements(&mut self, retires: &[(RequestId, u32)]) -> Result<(), WorkerDeath> {
        for i in 0..retires.len() {
            let (_, slot) = retires[i];
            for wi in 0..self.peers.len() {
                if let Err(d) = self.send_to(wi, WireMsg::Retire { slot }) {
                    // re-queue this one and everything unsent (leader contract)
                    for &(rid, rslot) in &retires[i..] {
                        self.sched.push_retirement(rid, rslot);
                    }
                    return Err(d);
                }
            }
        }
        Ok(())
    }

    /// `KvStatsReq` round-trip per link: the FIFO barrier that discards
    /// stale in-flight replies — including `KvStats` carrying a stale
    /// membership epoch — and returns the pool occupancy.
    fn barrier(&mut self) -> Result<KvCacheStats, WorkerDeath> {
        for wi in 0..self.peers.len() {
            self.send_to(wi, WireMsg::KvStatsReq)?;
        }
        let mut sum = KvCacheStats::default();
        for wi in 0..self.peers.len() {
            loop {
                match self.recv_worker(wi)? {
                    WireMsg::KvStats { stats, epoch } if epoch == self.epoch => {
                        sum = sum.merge(&stats);
                        break;
                    }
                    // pre-reshard traffic: fenced off by the epoch
                    _stale => {}
                }
            }
        }
        Ok(sum)
    }

    /// One chunked-prefill pass; returns the next-token prediction after
    /// the chunk's last valid row.
    fn prefill_chunk(
        &mut self,
        slot: u32,
        chunk: &[i32],
        cached: usize,
    ) -> Result<i32, WorkerDeath> {
        let valid = chunk.len();
        let w = self.peers.len();
        let group = HEADS / KV_HEADS;
        let mut hash = HASH_INIT;
        for layer in 0..LAYERS {
            let q = build(valid, HEADS, |r, h, d| q_val(chunk[r], cached + r, layer, h, d));
            let k = build(valid, KV_HEADS, |_r, h, d| k_val(layer, h, d));
            let v = build(valid, KV_HEADS, |r, h, d| v_val(chunk[r], cached + r, layer, h, d));
            for wi in 0..w {
                let r = self.plan[wi];
                let qr = r.q_range(group);
                self.send_to(
                    wi,
                    WireMsg::PrefillChunk {
                        layer,
                        slot,
                        q: slice_heads(&q, qr.start, qr.count),
                        k: slice_heads(&k, r.start, r.count),
                        v: slice_heads(&v, r.start, r.count),
                        cached: cached as i32,
                        valid,
                        seq_bucket: MAX_SEQ,
                    },
                )?;
            }
            let out = self.recv_attn(layer, valid)?;
            hash = fold_row(hash, &out[(valid - 1) * HEADS * HEAD_DIM..][..HEADS * HEAD_DIM]);
        }
        Ok((hash % VOCAB as u32) as i32)
    }

    /// One decode iteration for a batch group; returns next tokens.
    fn decode_rows(&mut self, rows: &[DecodeRow]) -> Result<Vec<i32>, WorkerDeath> {
        let b = rows.len();
        let w = self.peers.len();
        let group = HEADS / KV_HEADS;
        let slots: Vec<u32> = rows.iter().map(|r| r.slot).collect();
        let lens: Vec<i32> = rows.iter().map(|r| r.len).collect();
        let mut hashes = vec![HASH_INIT; b];
        for layer in 0..LAYERS {
            let q = build(b, HEADS, |r, h, d| {
                q_val(rows[r].input, rows[r].len as usize, layer, h, d)
            });
            let k = build(b, KV_HEADS, |_r, h, d| k_val(layer, h, d));
            let v = build(b, KV_HEADS, |r, h, d| {
                v_val(rows[r].input, rows[r].len as usize, layer, h, d)
            });
            for wi in 0..w {
                let qr = self.plan[wi].q_range(group);
                self.send_to(
                    wi,
                    WireMsg::StepQ {
                        layer,
                        slots: slots.clone(),
                        q: slice_heads(&q, qr.start, qr.count),
                        lens: lens.clone(),
                        seq_bucket: MAX_SEQ,
                        overlap: false,
                    },
                )?;
            }
            for wi in 0..w {
                let r = self.plan[wi];
                self.send_to(
                    wi,
                    WireMsg::StepKv {
                        layer,
                        k: slice_heads(&k, r.start, r.count),
                        v: slice_heads(&v, r.start, r.count),
                    },
                )?;
            }
            let out = self.recv_attn(layer, b)?;
            for (r, h) in hashes.iter_mut().enumerate() {
                *h = fold_row(*h, &out[r * HEADS * HEAD_DIM..][..HEADS * HEAD_DIM]);
            }
        }
        Ok(hashes.into_iter().map(|h| (h % VOCAB as u32) as i32).collect())
    }

    /// One engine iteration (the leader's `step_inner`, scripted).
    fn step_inner(&mut self) -> Result<bool, WorkerDeath> {
        let leftover = self.sched.take_retirements();
        self.send_retirements(&leftover)?;
        let _ = self.sched.admit(KvOccupancy::default());
        let _ = self.sched.take_admitted();
        if let Some(p) = self.sched.next_prefill() {
            let chunk = self.sched.prompt_chunk(p.id, PREFILL_CHUNK);
            let next = self.prefill_chunk(p.slot, &chunk, p.cached)?;
            self.sched.note_prefill_chunk(p.id, chunk.len(), next);
        } else {
            for rows in self.sched.decode_plan() {
                if rows.is_empty() {
                    continue;
                }
                let next = self.decode_rows(&rows)?;
                for (row, &tok) in rows.iter().zip(next.iter()) {
                    self.sched.note_decode(row.id, tok);
                }
            }
        }
        let _ = self.sched.take_finished();
        let retires = self.sched.take_retirements();
        self.send_retirements(&retires)?;
        Ok(self.sched.is_idle())
    }

    /// Preempt every live request back to the waiting queue and queue a
    /// `Retire` for every slot it held; returns the replay-token count
    /// (the leader's `preempt_all_live`, scripted).
    fn preempt_all(&mut self) -> u64 {
        let live = self.sched.live_ids();
        // capture slots first: a request caught mid-FIRST-prefill-chunk
        // shows wrote_kv = false (no Retire on preempt) but surviving
        // workers may hold its in-flight appends — retire explicitly
        let slots: Vec<(RequestId, Option<u32>)> =
            live.iter().map(|&id| (id, self.sched.slot_of(id))).collect();
        for &id in live.iter().rev() {
            self.sched.preempt(id);
        }
        let queued = self.sched.take_retirements();
        for &(id, slot) in &slots {
            let Some(slot) = slot else { continue };
            if !queued.iter().any(|&(_, qs)| qs == slot) {
                self.sched.push_retirement(id, slot);
            }
        }
        for (id, slot) in queued {
            self.sched.push_retirement(id, slot);
        }
        let mut replayed = 0u64;
        for &id in &live {
            if let Some(p) = self.sched.effective_prompt(id) {
                replayed += p.len() as u64;
            }
        }
        replayed
    }

    /// Epoch-fenced reshard over the current pool (the leader's
    /// `reshard_and_barrier`, scripted): bump the epoch, re-plan the
    /// contiguous head ranges, re-`Welcome` every member (the arena
    /// rebuild is an implicit retire-everything), flush queued Retires,
    /// then run the fenced barrier so no stale-epoch reply can alias.
    fn reshard(&mut self) -> Result<(), WorkerDeath> {
        self.epoch += 1;
        let _sp = obs::span("failover", "reshard").arg("epoch", self.epoch as i64);
        self.plan = head_ranges(KV_HEADS, self.peers.len()).map_err(|e| WorkerDeath {
            worker: 0,
            cause: DeathCause::Protocol(format!("shard plan: {e}")),
        })?;
        for wi in 0..self.peers.len() {
            let msg = self.welcome_msg(wi);
            self.send_to(wi, msg)?;
        }
        let retires = self.sched.take_retirements();
        self.send_retirements(&retires)?;
        let _ = self.barrier()?;
        // a surviving worker must not face its next fault with a ladder
        // already exhausted by this episode
        for p in &mut self.peers {
            p.health.reset();
        }
        Ok(())
    }

    /// The leader's recovery, scripted: preempt-replay plus either a
    /// same-width respawn or (respawn disabled) a graceful degradation
    /// to the survivors, both funneled through [`Chaos::reshard`].
    fn recover(&mut self, death: &WorkerDeath) -> Result<(), WorkerDeath> {
        // a rolled-back adoption surfaces the joiner's death with an
        // index one past the already-restored pool: nothing to recover
        if death.worker >= self.peers.len() {
            return Ok(());
        }
        let t0 = Instant::now();
        let degrade = !self.cfg.allow_respawn;
        let _sp = obs::span("failover", if degrade { "degrade" } else { "recover" })
            .arg("worker", death.worker as i64)
            .arg_str("cause", death.cause.name());
        // credit the replay at preemption time: a cascade (this recovery
        // tripping over another dead link) retries with nothing left to
        // preempt, so crediting only on success would under-count
        let replayed = self.preempt_all();
        self.tokens_replayed += replayed;
        if degrade {
            let policy =
                MembershipPolicy { allow_respawn: false, min_workers: self.cfg.min_workers };
            if !policy.can_degrade_to(self.peers.len() - 1) {
                // refuse below the floor: leave the pool as-is so the
                // cascade ladder sees a repeat death and aborts typed
                // (the queued Retires drain leak-free in `abort`)
                return Err(death.clone());
            }
            self.peers.remove(death.worker);
            self.degrades += 1;
        } else {
            self.peers[death.worker] = spawn_peer(self.cfg, death.worker, true).map_err(|e| {
                WorkerDeath { worker: death.worker, cause: DeathCause::Protocol(e) }
            })?;
            self.handshake_hello(death.worker)?;
        }
        self.reshard()?;
        self.recoveries += 1;
        self.metrics.record_recovery(replayed, t0.elapsed().as_secs_f64());
        if degrade {
            crate::metrics::note_degrade(t0.elapsed().as_secs_f64());
        }
        Ok(())
    }

    /// Scripted W→W+1 scale-up: spawn a joiner, handshake it, quiesce
    /// (preempt everything live), reshard the widened pool. On any
    /// failure the joiner is evicted and the original membership
    /// re-fenced before the error surfaces.
    fn adopt(&mut self) -> Result<(), WorkerDeath> {
        if self.peers.len() + 1 > KV_HEADS {
            return Ok(()); // no spare head range to give a joiner
        }
        let t0 = Instant::now();
        let new_idx = self.peers.len();
        let _sp = obs::span("failover", "adopt").arg("worker", new_idx as i64);
        // respawn=false so the fault plan may wrap the joiner — kills
        // inside the adoption window are a tested path
        let joiner = spawn_peer(self.cfg, new_idx, false)
            .map_err(|e| WorkerDeath { worker: new_idx, cause: DeathCause::Protocol(e) })?;
        self.peers.push(joiner);
        self.tokens_replayed += self.preempt_all();
        let res = self.handshake_hello(new_idx).and_then(|()| self.reshard());
        match res {
            Ok(()) => {
                self.adoptions += 1;
                crate::metrics::note_adoption(t0.elapsed().as_secs_f64());
                Ok(())
            }
            Err(d) => {
                // evict the joiner and re-fence the original members
                let mut p = self.peers.remove(new_idx);
                let _ = p.link.send(WireMsg::Shutdown);
                if let Some(t) = p.thread.take() {
                    let _ = t.join();
                }
                self.reshard()?;
                Err(d)
            }
        }
    }

    /// Cascade like the leader: recovery may trip over another dying
    /// link; give up (the caller aborts typed) if any worker needs
    /// recovering twice within one episode.
    fn recover_ladder(&mut self, death: WorkerDeath) -> Result<(), WorkerDeath> {
        let mut d = death;
        let mut tried: Vec<usize> = Vec::new();
        let mut width = self.peers.len();
        loop {
            if self.peers.len() < width {
                // a degradation removed a peer, shifting indices: restart
                // the repeat-death guard (the shrinking pool bounds this)
                width = self.peers.len();
                tried.clear();
            }
            if tried.contains(&d.worker) {
                return Err(d);
            }
            tried.push(d.worker);
            match self.recover(&d) {
                Ok(()) => return Ok(()),
                Err(d2) => d = d2,
            }
        }
    }

    /// Typed abort: cancel everything, flush retirements and count leaks
    /// on whichever links still answer, shut down.
    fn abort(&mut self, death: WorkerDeath) -> ChaosFailure {
        let ids: Vec<RequestId> = self.sched.live_ids();
        // every live slot gets a Retire regardless of scheduler-visible
        // progress (in-flight first chunks — see `recover`)
        let mut slots: Vec<u32> = ids.iter().filter_map(|&id| self.sched.slot_of(id)).collect();
        for id in ids {
            self.sched.cancel(id);
        }
        for (_, slot) in self.sched.take_retirements() {
            if !slots.contains(&slot) {
                slots.push(slot);
            }
        }
        for slot in slots {
            for wi in 0..self.peers.len() {
                let _ = self.peers[wi].link.send(WireMsg::Retire { slot });
            }
        }
        let mut leaked = 0usize;
        for wi in 0..self.peers.len() {
            if self.peers[wi].link.send(WireMsg::KvStatsReq).is_err() {
                continue; // dead link: its arena died with it
            }
            loop {
                match self.peers[wi].link.recv_timeout(Duration::from_millis(500)) {
                    Ok(Some(WireMsg::KvStats { stats, .. })) => {
                        leaked += stats.blocks_in_use;
                        break;
                    }
                    Ok(Some(_stale)) => {}
                    _ => break,
                }
            }
        }
        self.shutdown();
        ChaosFailure { death, leaked_blocks: leaked }
    }

    fn shutdown(&mut self) {
        for wi in 0..self.peers.len() {
            let _ = self.peers[wi].link.send(WireMsg::Shutdown);
        }
        for p in &mut self.peers {
            if let Some(t) = p.thread.take() {
                let _ = t.join();
            }
        }
    }
}

/// Run one chaos session to completion. Never panics on peer
/// misbehavior: faults either recover transparently (`auto_recover`) or
/// abort with a typed [`ChaosFailure`] after freeing all KV.
pub fn run_chaos(cfg: &ChaosCfg) -> Result<ChaosReport, ChaosFailure> {
    let mut h = match Chaos::new(cfg) {
        Ok(h) => h,
        Err(e) => {
            return Err(ChaosFailure {
                death: WorkerDeath { worker: 0, cause: DeathCause::Protocol(e) },
                leaked_blocks: 0,
            });
        }
    };
    let ids: Vec<RequestId> = (0..cfg.requests)
        .map(|r| {
            h.sched
                .submit(prompt_for(r), cfg.gen_tokens)
                .expect("chaos prompts are valid by construction")
        })
        .collect();

    let mut steps = 0usize;
    let mut adopted = cfg.adopt_at_step.is_none();
    let mut killed = vec![false; cfg.kill_at.len()];
    loop {
        // scripted membership events land at step boundaries, never
        // mid-step: exact degrade/adopt scripts stay deterministic
        if let Some(hook) = cfg.on_step {
            hook(steps);
        }
        for i in 0..cfg.kill_at.len() {
            let (at, wi) = cfg.kill_at[i];
            if !killed[i] && at <= steps {
                killed[i] = true;
                if wi < h.peers.len() {
                    h.inject_kill(wi);
                }
            }
        }
        if let Some(at) = cfg.adopt_at_step {
            if !adopted && steps >= at {
                adopted = true;
                if let Err(d) = h.adopt() {
                    if !cfg.auto_recover {
                        return Err(h.abort(d));
                    }
                    if let Err(d) = h.recover_ladder(d) {
                        return Err(h.abort(d));
                    }
                }
            }
        }
        match h.step_inner() {
            Ok(idle) => {
                steps += 1;
                if idle {
                    break;
                }
            }
            Err(death) => {
                if !cfg.auto_recover {
                    return Err(h.abort(death));
                }
                if let Err(d) = h.recover_ladder(death) {
                    return Err(h.abort(d));
                }
            }
        }
        if steps > 20_000 {
            let d = WorkerDeath {
                worker: 0,
                cause: DeathCause::Protocol("chaos session exceeded step cap".into()),
            };
            return Err(h.abort(d));
        }
    }

    // drained: the leak check must see zero mapped blocks pool-wide
    let stats = match h.barrier() {
        Ok(s) => s,
        Err(d) => return Err(h.abort(d)),
    };
    let outputs = ids
        .iter()
        .map(|&id| h.sched.poll(id).map(|s| s.tokens).unwrap_or_default())
        .collect();
    let final_workers = h.peers.len();
    h.shutdown();
    Ok(ChaosReport {
        outputs,
        worker_deaths: h.deaths,
        recoveries: h.recoveries,
        tokens_replayed: h.tokens_replayed,
        steps,
        leaked_blocks: stats.blocks_in_use,
        degrades: h.degrades,
        adoptions: h.adoptions,
        final_workers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_run_completes_clean() {
        let cfg = ChaosCfg::default();
        let r = run_chaos(&cfg).expect("golden run");
        assert_eq!(r.outputs.len(), cfg.requests);
        assert!(r.outputs.iter().all(|o| o.len() == cfg.gen_tokens));
        assert_eq!(r.worker_deaths, 0);
        assert_eq!(r.leaked_blocks, 0);
    }

    #[test]
    fn golden_run_is_deterministic() {
        let cfg = ChaosCfg::default();
        let a = run_chaos(&cfg).expect("run a");
        let b = run_chaos(&cfg).expect("run b");
        assert_eq!(a.outputs, b.outputs);
    }

    #[test]
    fn kill_mid_decode_recovers_bit_identical() {
        let golden = run_chaos(&ChaosCfg::default()).expect("golden");
        let mut cfg = ChaosCfg::default();
        // kill worker 1's link mid-decode (prefill is ~6 sends, decode
        // iterations are 4 sends each on this geometry)
        cfg.fault_plan = Some(FaultPlan::parse("worker=1,kill-send=20").expect("plan"));
        let faulted = run_chaos(&cfg).expect("faulted run must recover");
        assert!(faulted.worker_deaths >= 1, "the kill must have been detected");
        assert!(faulted.recoveries >= 1);
        assert!(faulted.tokens_replayed > 0);
        assert_eq!(faulted.leaked_blocks, 0);
        assert_eq!(faulted.outputs, golden.outputs, "recovery must be bit-identical");
    }

    #[test]
    fn no_recover_mode_fails_typed_without_leaks() {
        let mut cfg = ChaosCfg::default();
        cfg.fault_plan = Some(FaultPlan::parse("worker=0,kill-recv=5").expect("plan"));
        cfg.auto_recover = false;
        let err = run_chaos(&cfg).expect_err("death must surface typed");
        assert_eq!(err.death.worker, 0);
        assert_eq!(err.leaked_blocks, 0, "aborted session must free all KV");
    }
}
