//! Wire messages between the model worker (leader) and attention workers.
//!
//! These are the exact tensors the paper moves over the DCN each layer:
//! q right after Q-Proj+RoPE (the overlap path), k/v at slice end, and the
//! attention output back — plus the KV lifecycle control plane (`Retire`,
//! `KvStats*`) the paged arena needs.
//!
//! A `WireMsg` is transport-agnostic: it crosses whichever
//! [`crate::net::Transport`] the pipeline was started with. It is also
//! backend-agnostic: the same `StepQ`/`StepKv`/`PrefillChunk` stream feeds
//! either attention backend (`--attn-backend engine|native`) — the worker
//! decides locally whether the tensors are gathered for a PJRT artifact or
//! consumed in place by the block-table-native kernel.
//!
//! The wire is also **storage-dtype-agnostic**: K/V tensors always travel
//! f32 regardless of the workers' `--kv-dtype`. Quantization (f16/int8
//! block storage) is a worker-local decision applied at arena *append* —
//! keeping the protocol stable lets workers with different storage dtypes
//! coexist in one pool, keeps `attn_combine`'s new-token math exact, and
//! avoids coupling the codec to storage formats that only exist on one
//! side of the link. Only the `KvStats` snapshot reflects the dtype, via
//! its `bytes_in_use`/`total_bytes` fields.
//!
//! * Over the **in-process** link (`--transport inproc`,
//!   `net::inproc` → `netsim::transport`), tensor payloads are `Arc`-backed
//!   [`HostTensor`] views — a send moves a pointer on the host, mirroring
//!   RDMA's no-intermediate-copy property — and [`WireMsg::wire_bytes`]
//!   charges the *logical* payload size to the simulated network.
//! * Over the **TCP** transport (`--transport tcp`, `net::tcp`), every
//!   message is serialized through `net::codec` into a versioned,
//!   length-prefixed, checksummed frame (12-byte header: magic, version,
//!   type tag, payload length, FNV-1a checksum; tensors carry dtype/shape
//!   metadata) and the transport records *measured* frame bytes next to
//!   the same logical model — the per-class comparison lands in
//!   `ServeMetrics::wire_stats`.
//!
//! `wire_bytes()` therefore stays the single logical-size model both
//! transports account against; the codec's `encoded_len()` is the measured
//! counterpart it is validated with.

use crate::metrics::KvCacheStats;
use crate::runtime::host::HostTensor;

/// Messages on the leader↔worker link (one enum; the link is bidirectional).
#[derive(Debug, Clone, PartialEq)]
pub enum WireMsg {
    /// Membership handshake, worker → leader: the **first** frame on every
    /// link (spawned, respawned, or adopted). Carries the worker's codec
    /// version so incompatible peers fail typed before any tensor moves,
    /// and its spawn-time shard index for diagnostics. The worker sends
    /// nothing else until the leader's [`WireMsg::Welcome`] arrives.
    Hello {
        /// `net::codec::FORMAT_VERSION` the worker speaks; the leader
        /// rejects a mismatch as a `Protocol` death.
        codec_version: u32,
        /// Spawn-time shard index (diagnostic; the authoritative geometry
        /// arrives in `Welcome`).
        shard: u32,
    },
    /// Membership handshake reply, leader → worker: admits the worker into
    /// membership epoch `epoch` and assigns its KV-head range. The worker
    /// (re)builds its paged arena from these fields — a `Welcome` received
    /// mid-session is a **reshard**: drop every cached block, adopt the new
    /// range, echo the new epoch on subsequent `KvStats`.
    Welcome {
        /// Membership epoch this geometry belongs to (bumped on every
        /// respawn / degrade / adopt reshard).
        epoch: u64,
        /// First KV head of this worker's contiguous range.
        kv_start: u32,
        /// KV heads in the range (may differ across workers when the pool
        /// width does not divide the head count).
        kv_count: u32,
        /// Slot capacity to size the arena for.
        slots: u32,
        /// Tokens per KV block.
        kv_block_size: u32,
        /// Model layers.
        layers: u32,
        /// Head dimension.
        head_dim: u32,
        /// Max sequence length per slot.
        max_seq: u32,
    },
    /// Query shard for one layer step. Arrives first; in overlap mode the
    /// worker immediately starts partial attention over its cached tokens.
    StepQ {
        layer: usize,
        /// cache slot of each batch row (row i ↔ slot slots[i])
        slots: Vec<u32>,
        /// [bucket, H_shard, hd]
        q: HostTensor,
        /// valid cached tokens per row (before this step's append)
        lens: Vec<i32>,
        /// seq bucket to run the attention artifact at
        seq_bucket: usize,
        /// overlap mode: run attn_prev now, combine on KV arrival
        overlap: bool,
    },
    /// Key/value shard for the same (layer, step) as the last StepQ.
    StepKv {
        layer: usize,
        /// [bucket, KH_shard, hd]
        k: HostTensor,
        /// [bucket, KH_shard, hd]
        v: HostTensor,
    },
    /// Chunked-prefill step for ONE request (paper §5): the worker appends
    /// the chunk's K/V shard to the slot's paged cache and computes
    /// attention of the chunk over cached-prefix + intra-chunk-causal
    /// tokens.
    PrefillChunk {
        layer: usize,
        slot: u32,
        /// [T, H_shard, hd] chunk queries (T = chunk bucket, padded).
        q: HostTensor,
        /// [T, KH_shard, hd] chunk keys/values.
        k: HostTensor,
        v: HostTensor,
        /// valid cached tokens before this chunk.
        cached: i32,
        /// valid rows of the chunk (≤ T; the rest is padding).
        valid: usize,
        seq_bucket: usize,
    },
    /// Attention output shard [bucket, H_shard, hd] (worker → leader).
    AttnOut { layer: usize, out: HostTensor },
    /// The request in `slot` completed: free its KV blocks (leader →
    /// worker). Idempotent; a later occupant of the slot re-allocates.
    /// With refcounted blocks this *decrements* — blocks shared with other
    /// slots via `MapBlocks` stay resident for them.
    Retire { slot: u32 },
    /// Prefix sharing (leader → worker): map the first
    /// `ceil(tokens / block_size)` blocks of `src_slot`'s chain into `slot`
    /// read-only, covering `tokens` cached token slots. Slot layouts are
    /// mirrored across workers (each holds its KV-head shard of *every*
    /// request), so a slot-relative message needs no physical block ids on
    /// the wire. The destination writes copy-on-write.
    MapBlocks { slot: u32, src_slot: u32, tokens: usize },
    /// Ask for a KV-arena accounting snapshot (leader → worker).
    KvStatsReq,
    /// KV-arena accounting snapshot (worker → leader). `epoch` echoes the
    /// membership epoch of the worker's last `Welcome` — the leader's
    /// reshard barrier discards snapshots from a dead geometry by epoch
    /// mismatch, so stale in-flight replies can never alias into the new
    /// membership.
    KvStats { stats: KvCacheStats, epoch: u64 },
    /// Worker fatal error (worker → leader).
    WorkerError { msg: String },
    /// Graceful shutdown (leader → worker).
    Shutdown,
}

impl WireMsg {
    /// Bytes this message occupies on the wire (tensor payloads only).
    pub fn wire_bytes(&self) -> usize {
        match self {
            WireMsg::Hello { .. } => 8,
            WireMsg::Welcome { .. } => 36,
            WireMsg::StepQ { q, lens, slots, .. } => {
                q.byte_size() + lens.len() * 4 + slots.len() * 4
            }
            WireMsg::StepKv { k, v, .. } => k.byte_size() + v.byte_size(),
            WireMsg::PrefillChunk { q, k, v, .. } => {
                q.byte_size() + k.byte_size() + v.byte_size() + 8
            }
            WireMsg::AttnOut { out, .. } => out.byte_size(),
            WireMsg::Retire { .. } => 4,
            WireMsg::KvStatsReq => 0,
            WireMsg::KvStats { .. } => 72,
            WireMsg::WorkerError { msg } => msg.len(),
            WireMsg::Shutdown => 0,
            WireMsg::MapBlocks { .. } => 12,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_accounting() {
        let q = HostTensor::zeros_f32(vec![4, 4, 16]);
        let m = WireMsg::StepQ {
            layer: 0,
            slots: vec![0, 1, 2, 3],
            q,
            lens: vec![0; 4],
            seq_bucket: 64,
            overlap: false,
        };
        assert_eq!(m.wire_bytes(), 4 * 4 * 16 * 4 + 16 + 16);
        assert_eq!(WireMsg::Shutdown.wire_bytes(), 0);
        assert_eq!(WireMsg::Retire { slot: 3 }.wire_bytes(), 4);
        assert_eq!(WireMsg::KvStatsReq.wire_bytes(), 0);
        assert_eq!(
            WireMsg::KvStats { stats: KvCacheStats::default(), epoch: 0 }.wire_bytes(),
            72
        );
        assert_eq!(WireMsg::MapBlocks { slot: 1, src_slot: 0, tokens: 32 }.wire_bytes(), 12);
        assert_eq!(WireMsg::Hello { codec_version: 4, shard: 0 }.wire_bytes(), 8);
        let w = WireMsg::Welcome {
            epoch: 1,
            kv_start: 0,
            kv_count: 2,
            slots: 4,
            kv_block_size: 4,
            layers: 2,
            head_dim: 8,
            max_seq: 64,
        };
        assert_eq!(w.wire_bytes(), 36);
    }

    #[test]
    fn tensor_payloads_share_buffers_on_clone() {
        // a WireMsg clone (e.g. re-send) must not deep-copy tensor payloads
        let q = HostTensor::zeros_f32(vec![2, 2, 8]);
        let m = WireMsg::AttnOut { layer: 0, out: q.clone() };
        let m2 = m.clone();
        match (&m, &m2) {
            (WireMsg::AttnOut { out: a, .. }, WireMsg::AttnOut { out: b, .. }) => {
                assert!(a.shares_buffer(b));
            }
            _ => unreachable!(),
        }
    }
}
