//! The model worker / leader: drives the disaggregated decode pipeline on
//! the real tiny model through PJRT — slices on this thread (the
//! "compute-optimised device"), attention on worker threads (the
//! "memory-optimised pool"), tensors crossing the simulated network.
//!
//! # Serving surface: a step-driven, request-lifecycle engine
//!
//! Since the continuous-batching redesign the *engine*, not the caller,
//! owns slots, admission, and step composition. The public surface is
//! request-lifecycle-shaped:
//!
//! * [`DisaggPipeline::submit`] — validate one request (typed
//!   [`SubmitError`], per request — an invalid request no longer aborts a
//!   run) and queue it.
//! * [`DisaggPipeline::step`] — one engine iteration: admit from the
//!   waiting queue (pluggable [`crate::scheduler::AdmissionPolicy`],
//!   KV-budget aware in blocks or bytes), then run **either** one chunked-
//!   prefill pass for the oldest mid-prefill request **or** one decode
//!   iteration over the running batch, then retire finishes (freeing their
//!   KV blocks on every worker) — so requests join and leave the running
//!   batch at *iteration* granularity.
//! * [`DisaggPipeline::poll`] / [`DisaggPipeline::cancel`] — observe or
//!   abort an individual request at any point of its lifecycle.
//! * [`DisaggPipeline::drain`] — step until idle and take the session's
//!   [`ServeMetrics`] (throughput, TBT, per-request queue time and TTFT,
//!   KV and wire accounting).
//!
//! With `--prefix-cache`, admission probes a block-granular
//! [`PrefixIndex`] of live prefilled prompts and maps hits slot-to-slot
//! (`WireMsg::MapBlocks`, refcounted + copy-on-write on the workers)
//! instead of re-prefilling; with `--overcommit`, admission reserves
//! prompt-only KV and budget pressure preempts victims back to the queue
//! (their outputs unchanged — see the scheduler module docs).
//!
//! The scheduling *brain* lives in [`crate::scheduler`] — pure
//! bookkeeping, property-tested without artifacts; this module only
//! executes its plans against the engine and the attention workers.
//! Physical cache slots are an internal concern now: callers never pick
//! slot ids, and the slot→wire mapping (`StepQ.slots`,
//! `PrefillChunk.slot`, `Retire.slot`) is unchanged on the workers.
//!
//! `serve` survives as a thin driver loop over submit/step/drain (the CLI
//! and metrics report); `serve_waves` drives the same engine with the
//! legacy wave-partitioned grouping for comparison benches. `decode`
//! (teacher-forced golden semantics) and `generate` (chunked prefill +
//! decode) are drivers over the same surface.
//!
//! The paper's §4.2.2 overlap (send Q early, partial attention on the
//! workers, combine on K/V arrival) and §5 chunked prefill are unchanged
//! underneath; §4.3's staggered waves survive only as the
//! [`GroupMode::ByWave`] driver grouping.
//!
//! # Fault tolerance (paper §5)
//!
//! Every wire operation is typed: a worker that dies, hangs, or emits
//! garbage surfaces as a [`WorkerDeath`] error, never a panic. Receives
//! run under the [`HealthPolicy`] deadline/retry ladder (per-worker
//! [`HealthTracker`] strikes); fatal link errors and `WorkerError`
//! reports declare death immediately. When `auto_recover` is on (the
//! default), [`DisaggPipeline::step`] catches the death and runs the
//! preempt-replay-rebuild protocol documented in
//! [`crate::coordinator::failover`]: every live request is preempted
//! through the scheduler's promoted-token replay, a replacement worker is
//! spawned, surviving links are drained to a clean boundary (`KvStatsReq`
//! FIFO barrier), and serving resumes — recovered output bit-identical to
//! an unfailed run on the native backend. [`FaultPlan`]
//! (`--fault-plan`) arms deterministic fault injection on the leader-side
//! links for testing all of this.
//!
//! # Elastic membership
//!
//! The worker pool is no longer fixed-width. Every worker — spawned,
//! respawned, or adopted — joins through a versioned `Hello`/`Welcome`
//! handshake before any data-plane traffic; the `Welcome` carries its
//! negotiated contiguous KV-head range ([`crate::kvcache::ShardRange`]),
//! the arena geometry, and the current **membership epoch**. With
//! `--no-respawn`, a death *degrades* the pool instead of respawning: the
//! leader re-plans head ranges over the W−1 survivors and keeps serving
//! (bit-identical output on the native backend) down to the
//! `--min-workers` floor, below which the step fails with a typed
//! [`MembershipRefused`]. [`DisaggPipeline::adopt_worker`] reshards a
//! joining worker in at a step boundary (W→W+1). Every reshard bumps the
//! epoch and re-`Welcome`s every member; workers echo the epoch on
//! `KvStats`, so the post-reshard barrier can fence out in-flight replies
//! from a dead geometry — see [`crate::coordinator::failover`]'s
//! membership-lifecycle walkthrough.

use std::cell::RefCell;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::failover::{
    DeathCause, HealthPolicy, HealthTracker, MembershipPolicy, MembershipRefused, Verdict,
    WorkerDeath,
};
use crate::kernels::AttnBackendKind;
use crate::kvcache::{head_ranges, KvDtype, PrefixIndex, ShardRange};
use crate::metrics::{KvCacheStats, ServeMetrics, StepBreakdown};
use crate::net::{
    inproc, tcp, DeadTransport, FaultPlan, FaultTransport, Transport, TransportKind,
};
use crate::netsim::stack::{NetStackModel, LINE_RATE_400G};
use crate::obs;
use crate::runtime::engine::Engine;
use crate::runtime::host::{copies, HostTensor};
use crate::scheduler::{
    AdmissionKind, DecodeRow, GroupMode, KvBudget, KvOccupancy, RequestId, RequestState,
    RequestStatus, SchedCfg, Scheduler, StepOutcome, SubmitError,
};
use crate::trace::Request;

use super::attn_worker::{run_attn_worker, AttnWorkerCfg, ModelGeom, PAD_SLOT};
use super::messages::WireMsg;

/// Seed of the serve driver's synthetic prompt stream (`trace::synth_prompts`);
/// fixed so FIFO continuous-batching sessions reproduce the historical
/// wave-mode serve token-for-token.
const SERVE_PROMPT_SEED: u64 = 0x1a31a;

/// Pipeline options.
#[derive(Debug, Clone)]
pub struct PipelineOpts {
    pub artifacts_dir: std::path::PathBuf,
    /// Attention workers (contiguous head-range shards). Any width
    /// `1..=kv_heads` on the native backend; the engine backend's
    /// per-width artifacts still require the width to divide `kv_heads`.
    pub attn_workers: usize,
    /// §4.2.2 resource-utilisation overlapping.
    pub overlap: bool,
    pub stack: &'static NetStackModel,
    /// Network pacing factor (0 = functional only, 1 = modelled latencies).
    pub time_scale: f64,
    /// Decode batch-group size (max rows per engine decode call).
    pub slots: usize,
    /// Pre-compile every leader entry point at start (removes multi-ms
    /// lazy-compile spikes from the first requests' tail latency).
    pub warmup: bool,
    /// Physical-slot head-room factor: the engine may hold up to
    /// `slots × max_waves` live requests (sizes the workers' KV slot
    /// pools; the name is historical — waves are gone from the API).
    pub max_waves: usize,
    /// Use the chunked-prefill path for prompts (paper §5); otherwise
    /// prompts are teacher-forced through the decode path.
    pub use_prefill: bool,
    /// Token slots per KV block in the workers' paged arenas.
    pub kv_block_size: usize,
    /// Storage dtype of the workers' KV block buffers (`--kv-dtype`):
    /// f32 (default), f16, or int8 with per-block scales. A worker-local
    /// storage decision — the wire and the leader stay f32 — that
    /// halves/quarters per-step KV bytes read by the native backend and
    /// resident bytes per cached token (so a fixed `--kv-budget` holds
    /// proportionally more context; `ServeMetrics` reports the byte view).
    pub kv_dtype: KvDtype,
    /// Which wire the leader↔worker links run over (`--transport`).
    pub transport: TransportKind,
    /// Which compute backend the attention workers run (`--attn-backend`):
    /// `engine` (PJRT artifacts over gathered K/V) or `native` (pure-Rust
    /// block-table kernel reading the arena in place — zero per-step KV
    /// copies on the workers).
    pub attn_backend: AttnBackendKind,
    /// Admission-order policy of the request scheduler (`--admission`):
    /// `fifo` (arrival order, the legacy behavior) or `sjf` (shortest job
    /// first among deferred admissions, with FIFO aging so nothing
    /// starves).
    pub admission: AdmissionKind,
    /// Per-worker KV **byte** budget (`--kv-budget`). The preferred unit:
    /// with quantized block storage a block's byte size differs per
    /// worker, so bytes budget mixed `--kv-dtype` pools correctly. Takes
    /// precedence over `kv_block_budget` when both are set.
    pub kv_byte_budget: Option<usize>,
    /// Per-worker KV **block** budget (`--kv-budget-blocks`, the legacy
    /// spelling). `None` (and no byte budget) = admit unconditionally.
    /// With a budget, admission consults the workers' `KvStats` snapshot +
    /// the live full-context reservations and defers requests that would
    /// overflow (counted in `ServeMetrics::deferred_admissions`; both
    /// budget units are reported in `ServeMetrics`).
    pub kv_block_budget: Option<usize>,
    /// Prompt-prefix sharing (`--prefix-cache`): index live requests'
    /// prefilled prompts in a block-granular trie and, on a hit, map the
    /// donor's KV blocks into the new request's slot (refcounted, CoW on
    /// divergence) instead of re-prefilling them. A miss leaves the
    /// admission path bit-identical to a build without the index.
    pub prefix_cache: bool,
    /// Block-granular KV overcommit (`--overcommit`): admission reserves
    /// prompt-only KV and reservations grow with the context; when live
    /// usage crosses the budget, the scheduler preempts victims back to
    /// the queue (their KV retired, output unchanged on resume). Only
    /// meaningful with a KV budget.
    pub overcommit: bool,
    /// Structured per-decode-step tracing (`--step-trace`): emit one obs
    /// instant event per decode iteration carrying request ids, slots,
    /// context lengths and buckets (the old `LAMINA_STEP_TRACE` eprintln,
    /// now a JSONL-exportable event). Records only while `obs::trace`
    /// collection is enabled (the CLI enables it for the run).
    pub step_trace: bool,
    /// Deterministic fault injection (`--fault-plan`): wrap the leader
    /// side of matching worker links in a [`FaultTransport`] applying the
    /// plan's drop/delay/corrupt/kill schedule. `None` (or an unarmed
    /// plan) leaves the links untouched — zero cost on the healthy path.
    /// Respawned replacement workers are never wrapped, so kill schedules
    /// fire once and a faulted run still terminates.
    pub fault_plan: Option<FaultPlan>,
    /// Worker-death detection knobs: per-attempt receive deadline, retry
    /// count and backoff (`--recv-deadline-ms`, `--recv-retries`).
    pub health: HealthPolicy,
    /// Recover from worker deaths inside [`DisaggPipeline::step`]
    /// (preempt-replay-rebuild) instead of surfacing the [`WorkerDeath`]
    /// to the caller. On by default; tests that assert on the typed error
    /// turn it off.
    pub auto_recover: bool,
    /// Respawn a replacement on worker death (`--no-respawn` clears it).
    /// When cleared, a death **degrades** the pool instead: the leader
    /// re-plans head ranges over the W−1 survivors and keeps serving —
    /// bit-identical output on the native backend — down to the
    /// `min_workers` floor, below which the step fails with a typed
    /// [`MembershipRefused`].
    pub allow_respawn: bool,
    /// Smallest pool width degradation may leave (`--min-workers`;
    /// effective minimum 1).
    pub min_workers: usize,
    /// Remote cluster mode (`--workers addr1,addr2,…`): instead of
    /// spawning in-process worker threads, dial standalone `lamina-attn`
    /// processes — worker `i` connects to `worker_addrs[i]`. A recovery
    /// respawn re-dials the same address (the worker binary's accept loop
    /// takes the leader back), and adoption consumes the next spare
    /// address beyond the starting width. The links speak the same tcp
    /// framing as loopback pairs, so failover, fault plans, and the
    /// `Hello`/`Welcome` handshake behave identically.
    pub worker_addrs: Option<Vec<crate::net::Addr>>,
}

impl PipelineOpts {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        PipelineOpts {
            artifacts_dir: artifacts_dir.into(),
            attn_workers: 2,
            overlap: true,
            stack: &crate::netsim::stack::FHBN,
            time_scale: 0.0,
            slots: 8,
            warmup: true,
            max_waves: 2,
            use_prefill: true,
            kv_block_size: 16,
            kv_dtype: KvDtype::F32,
            transport: TransportKind::Inproc,
            attn_backend: AttnBackendKind::Engine,
            admission: AdmissionKind::Fifo,
            kv_byte_budget: None,
            kv_block_budget: None,
            prefix_cache: false,
            overcommit: false,
            step_trace: false,
            fault_plan: None,
            health: HealthPolicy::default(),
            auto_recover: true,
            allow_respawn: true,
            min_workers: 1,
            worker_addrs: None,
        }
    }
}

struct WorkerHandle {
    link: Box<dyn Transport>,
    thread: Option<std::thread::JoinHandle<()>>,
    /// Strike counter of the death-detection retry ladder (see
    /// [`crate::coordinator::failover`]). RefCell: wire helpers take
    /// `&self`, and the pipeline is single-threaded on the leader side.
    health: RefCell<HealthTracker>,
}

/// Dial a standalone `lamina-attn` worker with bounded retry on the
/// health policy's backoff ladder: attempt `k` gets a connect deadline of
/// `attempt_deadline(k)`, with a short pause between attempts (a refused
/// connection returns instantly, so the pause is what gives a
/// still-starting worker its grace window). A worker that never comes up
/// is a typed error naming the address — never a hang.
pub fn dial_worker(
    addr: &crate::net::Addr,
    policy: &HealthPolicy,
) -> std::result::Result<tcp::TcpTransport, String> {
    let sa = addr.resolve().map_err(|e| e.to_string())?;
    let attempts = policy.attempts().max(1);
    let mut last = String::new();
    for k in 0..attempts {
        let _sp = obs::span("wire", "dial").arg("attempt", k as i64);
        match tcp::TcpTransport::connect_timeout(sa, policy.attempt_deadline(k)) {
            Ok(t) => return Ok(t),
            Err(e) => {
                last = e.to_string();
                if k + 1 < attempts {
                    std::thread::sleep(policy.attempt_deadline(k).min(Duration::from_millis(250)));
                }
            }
        }
    }
    Err(format!("dial {addr}: no worker after {attempts} attempts: {last}"))
}

/// Spawn one attention-worker connected over the configured transport: a
/// paced in-process channel, a real TCP loopback socket carrying
/// serialized `net::codec` frames, or — with `worker_addrs` — an outbound
/// dial to a standalone `lamina-attn` process. On the first spawn (not a
/// recovery respawn), the leader-side link endpoint is wrapped in a
/// [`FaultTransport`] when the pipeline's fault plan targets this worker.
fn spawn_worker(opts: &PipelineOpts, geom: ModelGeom, idx: usize, respawn: bool) -> Result<WorkerHandle> {
    // remote cluster: worker `idx` lives at `worker_addrs[idx]`; a respawn
    // re-dials the same address (the binary's accept loop takes us back)
    if let Some(addrs) = &opts.worker_addrs {
        let addr = addrs.get(idx).ok_or_else(|| {
            anyhow!(
                "no --workers address for worker {idx} (got {}; respawn re-dials, adoption \
                 needs a spare address)",
                addrs.len()
            )
        })?;
        let mut link: Box<dyn Transport> =
            Box::new(dial_worker(addr, &opts.health).map_err(|e| anyhow!(e))?);
        if !respawn {
            if let Some(plan) = &opts.fault_plan {
                if plan.is_armed() && plan.applies_to(idx) {
                    link = Box::new(FaultTransport::new(link, plan.clone(), idx as u64));
                }
            }
        }
        // no thread: the subprocess owns its own lifetime
        return Ok(WorkerHandle {
            link,
            thread: None,
            health: RefCell::new(HealthTracker::default()),
        });
    }
    let cfg = AttnWorkerCfg {
        artifacts_dir: opts.artifacts_dir.clone(),
        shard: idx,
        n_shards: opts.attn_workers,
        // the engine may keep up to slots × max_waves requests live
        slots: opts.slots * opts.max_waves,
        kv_block_size: opts.kv_block_size,
        kv_dtype: opts.kv_dtype,
        backend: opts.attn_backend,
        // the leader always has a manifest; handing the geometry over keeps
        // native workers artifact-independent
        geom: Some(geom),
        trust_welcome: false,
    };
    let name = if respawn { format!("lamina-attn-{idx}-r") } else { format!("lamina-attn-{idx}") };
    let builder = std::thread::Builder::new().name(name);
    let (mut link, thread): (Box<dyn Transport>, _) = match opts.transport {
        TransportKind::Inproc => {
            let (leader_end, worker_end) =
                inproc::pair(opts.stack, LINE_RATE_400G, opts.time_scale);
            let thread = builder
                .spawn(move || run_attn_worker(cfg, worker_end))
                .context("spawn attention worker")?;
            (Box::new(leader_end), thread)
        }
        TransportKind::Tcp => {
            let (leader_end, worker_end) = tcp::pair().context("tcp loopback pair")?;
            let thread = builder
                .spawn(move || run_attn_worker(cfg, worker_end))
                .context("spawn attention worker")?;
            (Box::new(leader_end), thread)
        }
    };
    // replacement workers are never fault-wrapped: kill schedules fire
    // once, so a faulted run recovers and terminates
    if !respawn {
        if let Some(plan) = &opts.fault_plan {
            if plan.is_armed() && plan.applies_to(idx) {
                link = Box::new(FaultTransport::new(link, plan.clone(), idx as u64));
            }
        }
    }
    Ok(WorkerHandle { link, thread: Some(thread), health: RefCell::new(HealthTracker::default()) })
}

/// One serving session's engine-side state: the scheduler (control plane)
/// plus per-session accounting. Reset by [`DisaggPipeline::begin_session`].
struct Session {
    sched: Scheduler,
    metrics: ServeMetrics,
    /// Block-granular prompt-prefix index (`Some` iff `--prefix-cache`):
    /// holds every live request whose prefill completed; admissions probe
    /// it and map hits from the donor's slot instead of re-prefilling.
    prefix: Option<PrefixIndex>,
    /// Latest pool-wide KvStats snapshot (feeds the next admission round).
    kv_snap: KvCacheStats,
    /// Endpoint wire counters at session start (report this session only).
    wire_baseline: crate::net::WireStats,
    /// KV budget in both units (for `ServeMetrics` reporting).
    budget_blocks: Option<usize>,
    budget_bytes: Option<usize>,
}

/// The disaggregated serving pipeline.
pub struct DisaggPipeline {
    engine: Engine,
    workers: Vec<WorkerHandle>,
    opts: PipelineOpts,
    /// network bytes sent per decode step (for breakdown accounting)
    step_net_bytes: std::cell::Cell<usize>,
    /// Wire counters of links whose workers were replaced (fault
    /// tolerance) — folded into `wire_stats` so pool totals survive
    /// recovery.
    retired_wire: crate::net::WireStats,
    /// The current serving session (always present after `start`).
    session: Option<Session>,
    /// Per-worker contiguous KV-head ranges (the shard plan); always the
    /// same length as `workers`. Re-planned on every membership change.
    plan: Vec<ShardRange>,
    /// Membership epoch: bumped on every reshard and carried by `Welcome`;
    /// workers echo it on `KvStats` so barriers can fence stale replies.
    epoch: u64,
}

impl DisaggPipeline {
    /// Start the pipeline: loads the leader engine, spawns the attention
    /// worker threads (each builds its own engine), and opens the default
    /// continuous-batching session.
    pub fn start(opts: PipelineOpts) -> Result<Self> {
        let engine = Engine::load(&opts.artifacts_dir)?;
        if opts.warmup {
            // compile only the leader-side entry points (slices); attention
            // artifacts belong to the workers' engines
            for e in &engine.manifest.entrypoints {
                if e.entry.starts_with("slice_") {
                    engine.execute_warm(&e.entry, e.batch, e.seq)?;
                }
            }
        }
        let mc = &engine.manifest.config;
        if opts.attn_workers == 0 || opts.attn_workers > mc.kv_heads {
            bail!(
                "attention workers ({}) must be 1..={} (every worker needs ≥1 kv head)",
                opts.attn_workers,
                mc.kv_heads
            );
        }
        // the native backend computes any contiguous head range in pure
        // Rust; only the engine backend's per-width attention artifacts
        // still require uniform shards
        if opts.attn_backend == AttnBackendKind::Engine && mc.kv_heads % opts.attn_workers != 0 {
            bail!(
                "attention workers ({}) must divide kv heads ({}) on the engine backend",
                opts.attn_workers,
                mc.kv_heads
            );
        }
        if opts.min_workers > opts.attn_workers {
            bail!(
                "--min-workers {} exceeds the starting pool of {} workers",
                opts.min_workers,
                opts.attn_workers
            );
        }
        if let Some(addrs) = &opts.worker_addrs {
            if addrs.len() < opts.attn_workers {
                bail!(
                    "--workers lists {} addresses but {} workers requested",
                    addrs.len(),
                    opts.attn_workers
                );
            }
        }
        // the native backend computes any shard width in pure Rust; only the
        // engine backend depends on per-width attention artifacts
        let shard_ok = opts.attn_backend == AttnBackendKind::Native
            || opts.attn_workers == 1
            || engine
                .manifest
                .entrypoints
                .iter()
                .any(|e| e.entry == format!("attention_w{}", opts.attn_workers));
        if !shard_ok {
            bail!("no attention artifacts for {} shards — re-run `make artifacts`",
                opts.attn_workers);
        }

        let plan =
            head_ranges(mc.kv_heads, opts.attn_workers).map_err(|e| anyhow!("shard plan: {e}"))?;
        let geom = ModelGeom::of(mc);
        let mut workers = Vec::new();
        for w in 0..opts.attn_workers {
            workers.push(spawn_worker(&opts, geom, w, false)?);
        }
        let mut pipe = DisaggPipeline {
            engine,
            workers,
            opts,
            step_net_bytes: std::cell::Cell::new(0),
            retired_wire: crate::net::WireStats::new(),
            session: None,
            plan,
            epoch: 1,
        };
        // membership handshake: every worker completes Hello → Welcome
        // before any data-plane traffic (begin_session may poll KvStats
        // immediately when a budget is set)
        for wi in 0..pipe.workers.len() {
            pipe.handshake_hello(wi)?;
            let msg = pipe.welcome_msg(wi);
            pipe.send_to(wi, msg)?;
        }
        let waves = pipe.opts.max_waves;
        pipe.begin_session(GroupMode::Packed, waves)?;
        Ok(pipe)
    }

    pub fn config(&self) -> &crate::runtime::manifest::ModelCfg {
        &self.engine.manifest.config
    }

    pub fn engine_stats(&self) -> crate::runtime::engine::EngineStats {
        self.engine.snapshot_stats()
    }

    /// Live attention-worker count (shrinks on degrade, grows on adoption).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Current membership epoch (bumped on every reshard; starts at 1).
    pub fn membership_epoch(&self) -> u64 {
        self.epoch
    }

    /// The current shard plan: worker → contiguous KV-head range.
    pub fn shard_plan(&self) -> &[ShardRange] {
        &self.plan
    }

    // ---- membership handshake ---------------------------------------------

    /// Leader side of the membership handshake: every freshly spawned
    /// link's first frame is the worker's `Hello`; validate its codec
    /// version before opening the data plane with a `Welcome`. A version
    /// mismatch or any other first frame is a protocol death.
    fn handshake_hello(&self, wi: usize) -> Result<()> {
        let t0 = Instant::now();
        match self.recv_worker(wi)? {
            WireMsg::Hello { codec_version, shard: _ } => {
                if codec_version != crate::net::codec::FORMAT_VERSION as u32 {
                    return Err(self.declare_dead(
                        wi,
                        DeathCause::Protocol(format!(
                            "worker speaks codec v{codec_version}, leader v{}",
                            crate::net::codec::FORMAT_VERSION
                        )),
                        t0,
                    ));
                }
                Ok(())
            }
            other => Err(self.declare_dead(
                wi,
                DeathCause::Protocol(format!("expected Hello, got {other:?}")),
                t0,
            )),
        }
    }

    /// Build worker `wi`'s `Welcome` from the current plan and epoch: its
    /// negotiated KV-head range plus the arena geometry it must (re)build.
    fn welcome_msg(&self, wi: usize) -> WireMsg {
        let mc = self.config();
        let r = self.plan[wi];
        WireMsg::Welcome {
            epoch: self.epoch,
            kv_start: r.start as u32,
            kv_count: r.count as u32,
            slots: (self.opts.slots * self.opts.max_waves) as u32,
            kv_block_size: self.opts.kv_block_size as u32,
            layers: mc.layers as u32,
            head_dim: mc.head_dim as u32,
            max_seq: mc.max_seq as u32,
        }
    }

    // ---- session lifecycle ------------------------------------------------

    /// Open a fresh serving session: a new scheduler (grouping + slot
    /// capacity `slots × waves`), fresh metrics, and a fresh wire/KV
    /// baseline. The previous session must be idle (no live requests);
    /// its finished requests stop being pollable. Drivers (`serve`,
    /// `decode`, `generate`, tests) call this; plain `submit`/`step` users
    /// keep the default session opened at `start` (Packed, full capacity).
    pub fn begin_session(&mut self, grouping: GroupMode, waves: usize) -> Result<()> {
        if let Some(s) = &self.session {
            if !s.sched.is_idle() {
                bail!("cannot reset the serving session while requests are live");
            }
        }
        assert!(waves >= 1, "need at least one wave of slots");
        assert!(
            waves <= self.opts.max_waves,
            "waves {waves} exceed max_waves {} (slot pools)",
            self.opts.max_waves
        );
        // endpoint counters run from pipeline start; the session reports
        // only its own traffic — snapshot BEFORE the first control-plane
        // poll so the poll itself is accounted (as the wave loop did)
        let wire_baseline = self.wire_stats();
        let budget = match (self.opts.kv_byte_budget, self.opts.kv_block_budget) {
            (Some(bytes), _) => KvBudget::Bytes(bytes),
            (None, Some(blocks)) => KvBudget::Blocks(blocks),
            (None, None) => KvBudget::Unlimited,
        };
        // the startup snapshot feeds only budget accounting (occupancy +
        // the per-worker block byte size for unit conversion); without a
        // budget, skip the control-plane round-trip entirely
        let kv_snap = if budget == KvBudget::Unlimited {
            KvCacheStats::default()
        } else {
            self.kv_stats()?
        };
        // per-worker bytes of one block (all layers, K+V, dtype-aware):
        // the merged snapshot sums blocks and bytes across workers, so the
        // ratio is exactly one worker-shard block
        let block_bytes =
            if kv_snap.total_blocks > 0 { kv_snap.total_bytes / kv_snap.total_blocks } else { 0 };
        let (budget_blocks, budget_bytes) = match budget {
            KvBudget::Unlimited => (None, None),
            KvBudget::Blocks(b) => (Some(b), (block_bytes > 0).then_some(b * block_bytes)),
            KvBudget::Bytes(b) => ((block_bytes > 0).then(|| b / block_bytes), Some(b)),
        };
        let mc = &self.engine.manifest.config;
        let mut sched = Scheduler::new(
            SchedCfg {
                max_context: mc.max_seq - 1,
                total_slots: self.opts.slots * waves,
                group_slots: self.opts.slots,
                grouping,
                use_prefill: self.opts.use_prefill,
                kv_block_size: self.opts.kv_block_size,
                block_bytes,
                budget,
                overcommit: self.opts.overcommit,
            },
            self.opts.admission.build(),
        );
        // ids stay unique across sessions: a stale id from the previous
        // session must poll as unknown, never alias a new request
        if let Some(prev) = &self.session {
            sched.resume_ids_at(prev.sched.next_request_id());
        }
        self.session = Some(Session {
            sched,
            metrics: ServeMetrics::new(),
            prefix: self.opts.prefix_cache.then(|| PrefixIndex::new(self.opts.kv_block_size)),
            kv_snap,
            wire_baseline,
            budget_blocks,
            budget_bytes,
        });
        Ok(())
    }

    fn session_ref(&self) -> &Session {
        self.session.as_ref().expect("serving session exists after start")
    }

    fn session_mut(&mut self) -> &mut Session {
        self.session.as_mut().expect("serving session exists after start")
    }

    // ---- request-lifecycle API (the primary serving surface) --------------

    /// Validate and queue one request: `prompt` is processed per the
    /// session default (chunked prefill or teacher-forced decode), then
    /// `gen_tokens` tokens are greedy-decoded. Returns the request's id,
    /// or a typed per-request [`SubmitError`] — the session is untouched
    /// either way.
    pub fn submit(&mut self, prompt: Vec<i32>, gen_tokens: usize) -> Result<RequestId, SubmitError> {
        self.session_mut().sched.submit(prompt, gen_tokens)
    }

    /// [`Self::submit`] with an explicit prompt-processing mode
    /// (`use_prefill = false` forces the teacher-forced golden `decode`
    /// semantics regardless of the session default).
    pub fn submit_with_mode(
        &mut self,
        prompt: Vec<i32>,
        gen_tokens: usize,
        use_prefill: bool,
    ) -> Result<RequestId, SubmitError> {
        self.session_mut().sched.submit_with_mode(prompt, gen_tokens, use_prefill)
    }

    /// One engine iteration: admit, then one prefill chunk **or** one
    /// decode pass over the running batch (grouped by the session's
    /// [`GroupMode`]), then retire finishes and refresh the KV snapshot.
    ///
    /// An attention-worker death mid-iteration does not panic and (with
    /// `auto_recover`, the default) does not error: the iteration's
    /// partial work is abandoned, recovery preempts every live request
    /// and respawns the worker, and the outcome reports the death via
    /// [`StepOutcome::recovered_workers`] (the preempted ids replay
    /// through the normal admission path on later steps, bit-identical).
    /// With `auto_recover` off the typed [`WorkerDeath`] surfaces in the
    /// `Err` for the caller to downcast.
    pub fn step(&mut self) -> Result<StepOutcome> {
        match self.step_inner() {
            Ok(o) => Ok(o),
            Err(e) => self.catch_death(e),
        }
    }

    /// Recovery path of [`Self::step`]: classify the error and, for
    /// worker deaths under `auto_recover`, run preempt-replay-rebuild.
    /// Recovery itself may trip over *another* dying link (multi-fault
    /// plans); the loop recovers each in turn, giving up only if the same
    /// worker dies twice in one iteration.
    fn catch_death(&mut self, e: anyhow::Error) -> Result<StepOutcome> {
        let mut outcome = StepOutcome::default();
        let mut err = e;
        let mut tried: Vec<usize> = Vec::new();
        let mut width = self.workers.len();
        loop {
            let death = match err.downcast::<WorkerDeath>() {
                Ok(d) => d,
                Err(other) => return Err(other),
            };
            if !self.opts.auto_recover || self.session.is_none() {
                return Err(anyhow::Error::new(death));
            }
            if self.workers.len() < width {
                // a degradation removed a member, so worker indices have
                // shifted: the repeat-death guard restarts (the shrinking
                // pool itself bounds this loop)
                width = self.workers.len();
                tried.clear();
            }
            if tried.contains(&death.worker) {
                // its own replacement died during recovery — unrecoverable
                return Err(anyhow::Error::new(death));
            }
            tried.push(death.worker);
            match self.recover_from_death(death.worker, &death.cause) {
                Ok(preempted) => {
                    outcome.recovered_workers.push(death.worker);
                    for id in preempted {
                        if !outcome.preempted.contains(&id) {
                            outcome.preempted.push(id);
                        }
                    }
                    break;
                }
                Err(e2) => err = e2,
            }
        }
        outcome.idle = self.session_ref().sched.is_idle();
        Ok(outcome)
    }

    fn step_inner(&mut self) -> Result<StepOutcome> {
        let _sp_step = obs::span("leader", "step");
        let workers_n = self.workers.len().max(1);
        let mut outcome = StepOutcome::default();

        // flush retirements left over from a failed cancel-time send
        // BEFORE admission can reassign the freed slot — a stale Retire
        // sent after the new occupant's appends would wipe its KV
        let leftover = self.session_mut().sched.take_retirements();
        self.send_retirements(&leftover)?;

        // admission against the latest per-worker occupancy
        {
            let _sp = obs::span("sched", "admit");
            let s = self.session_mut();
            let occ = KvOccupancy {
                blocks_in_use: s.kv_snap.blocks_in_use.div_ceil(workers_n),
                bytes_in_use: s.kv_snap.bytes_in_use.div_ceil(workers_n),
            };
            let (admitted, deferred) = s.sched.admit(occ);
            if deferred {
                s.metrics.record_deferred_admission();
            }
            outcome.admitted = admitted;
            outcome.deferred = deferred;
        }

        // prefix-cache probe for this round's admissions: on a hit, map
        // the donor's shared prompt blocks into the new slot instead of
        // re-prefilling them. MapBlocks goes out before any Retire this
        // step can queue, and wire order is FIFO per link, so the
        // refcounts land while the donor's blocks are still resident.
        let admitted_ids = self.session_mut().sched.take_admitted();
        if self.session_ref().prefix.is_some() {
            for id in admitted_ids {
                let hit = {
                    let s = self.session_ref();
                    // only requests awaiting their first prefill chunk can
                    // skip work (teacher-forced/single-token paths cannot)
                    if s.sched.poll(id).map(|st| st.state) != Some(RequestState::Prefilling) {
                        continue;
                    }
                    let prompt = s.sched.effective_prompt(id).expect("just admitted");
                    s.prefix.as_ref().expect("checked").lookup(&prompt, usize::MAX)
                };
                let Some(hit) = hit else { continue };
                let s = self.session_ref();
                let (Some(src), Some(dst)) = (s.sched.slot_of(hit.donor), s.sched.slot_of(id))
                else {
                    continue;
                };
                self.map_blocks(dst, src, hit.tokens)?;
                let s = self.session_mut();
                s.sched.set_prefix_cached(id, hit.tokens);
                s.metrics.record_prefix_hit(hit.tokens);
            }
        }

        // one prefill chunk (admission order), or one decode iteration
        let next_prefill = self.session_ref().sched.next_prefill();
        if let Some(p) = next_prefill {
            let cap = self.max_batch_bucket()?;
            let chunk = self.session_ref().sched.prompt_chunk(p.id, cap);
            let next = self.exec_prefill_chunk(p.slot, &chunk, p.cached)?;
            let s = self.session_mut();
            s.sched.note_prefill_chunk(p.id, chunk.len(), next);
            // prefill complete → the prompt's KV is durable on every
            // worker: index this request as a prefix donor (dropped again
            // on finish/cancel/preempt)
            if s.sched.poll(p.id).map(|st| st.state) == Some(RequestState::Decoding) {
                if let Some(ix) = s.prefix.as_mut() {
                    let prompt = s.sched.effective_prompt(p.id).expect("live");
                    ix.insert(p.id, &prompt);
                }
            }
            outcome.prefilled = Some(p.id);
        } else {
            let plan = {
                let _sp = obs::span("sched", "decode_plan");
                self.session_ref().sched.decode_plan()
            };
            for rows in plan {
                if rows.is_empty() {
                    continue;
                }
                // only decode-phase tokens count toward serving metrics
                let emitting = rows.iter().filter(|r| r.emits).count();
                let (next, bd) = self.decode_step_rows(&rows)?;
                let s = self.session_mut();
                for (row, &tok) in rows.iter().zip(next.iter()) {
                    s.sched.note_decode(row.id, tok);
                }
                if emitting > 0 {
                    s.metrics.record_step(emitting, bd);
                }
                outcome.decoded_rows += rows.len();
                outcome.decode_groups += 1;
            }
        }

        // overcommit pressure valve: preempt victims until the budget
        // holds again. Their Retires queue now and go out with this
        // step's batch; blocks a sharer mapped stay resident (refcounts).
        {
            let _sp = obs::span("sched", "pressure_preempt");
            let s = self.session_mut();
            let occ = KvOccupancy {
                blocks_in_use: s.kv_snap.blocks_in_use.div_ceil(workers_n),
                bytes_in_use: s.kv_snap.bytes_in_use.div_ceil(workers_n),
            };
            let preempted = s.sched.pressure_preempt(occ);
            if !preempted.is_empty() {
                s.metrics.record_preemptions(preempted.len() as u64);
                if let Some(ix) = s.prefix.as_mut() {
                    for &id in &preempted {
                        ix.remove(id);
                    }
                }
                outcome.preempted = preempted;
            }
        }

        // retire finishes: finish EVENTS (all finishes) drive outcome and
        // per-request metrics; RETIREMENTS (only finishes that materialized
        // KV) drive the Retire wire messages.
        let _sp_retire = obs::span("sched", "retire");
        let finished_ids = self.session_mut().sched.take_finished();
        let retires = self.session_mut().sched.take_retirements();
        let did_work = outcome.admitted > 0
            || outcome.prefilled.is_some()
            || outcome.decoded_rows > 0
            || !finished_ids.is_empty()
            || !retires.is_empty();
        // A snapshot costs one control-plane round-trip per worker, so only
        // refresh when it is consumed: every productive step when the KV
        // budget is bounded (admission reads it), otherwise only on steps
        // that retire something. Occupancy is non-decreasing between
        // retires and the snapshot lands before the Retire messages, so
        // retire-step snapshots still capture the exact peak.
        let budget_bounded =
            !matches!(self.session_ref().sched.cfg().budget, KvBudget::Unlimited);
        if did_work && (budget_bounded || !retires.is_empty()) {
            let snap = self.kv_stats()?;
            let s = self.session_mut();
            s.kv_snap = snap;
            s.metrics.record_kv(snap);
        }
        self.send_retirements(&retires)?;
        let mut completed = 0u64;
        for &id in &finished_ids {
            let s = self.session_mut();
            if let Some(ix) = s.prefix.as_mut() {
                ix.remove(id); // retired KV must stop being a donor
            }
            if let Some((queue_s, ttft_s, tokens)) = s.sched.lifecycle(id) {
                s.metrics.record_request(queue_s, ttft_s, tokens as u64);
                completed += 1;
            }
            outcome.finished.push(id);
        }
        let s = self.session_mut();
        s.metrics.record_completion(completed);
        outcome.idle = s.sched.is_idle();
        Ok(outcome)
    }

    /// Send `Retire` for each pending retirement; on a send failure the
    /// failed entry AND everything not yet sent are re-queued so a later
    /// step retries them (never silently dropped), and the transport error
    /// propagates.
    fn send_retirements(&mut self, retires: &[(RequestId, u32)]) -> Result<()> {
        for i in 0..retires.len() {
            let (_, slot) = retires[i];
            if let Err(e) = self.retire_slot(slot) {
                let s = self.session_mut();
                for &(rid, rslot) in &retires[i..] {
                    s.sched.push_retirement(rid, rslot);
                }
                return Err(e);
            }
        }
        Ok(())
    }

    /// Observe a request: lifecycle state, tokens generated so far, queue
    /// delay and TTFT once known. `None` for ids the current session does
    /// not know.
    pub fn poll(&self, id: RequestId) -> Option<RequestStatus> {
        self.session.as_ref().and_then(|s| s.sched.poll(id))
    }

    /// Cancel a request (queued → dropped; live → retired as
    /// `Finished(Cancelled)` with its KV blocks freed on the workers
    /// immediately).
    pub fn cancel(&mut self, id: RequestId) -> bool {
        let cancelled = self.session.as_mut().map_or(false, |s| s.sched.cancel(id));
        if cancelled {
            if let Some(ix) = self.session.as_mut().and_then(|s| s.prefix.as_mut()) {
                ix.remove(id);
            }
            // flush the retirement NOW (wire order is FIFO, so this is
            // race-free while the slot is still unassigned). A failed send
            // is re-queued and retried at the START of the next step —
            // i.e. still before admission could hand the slot out — where
            // the transport error surfaces through step()'s Result.
            let retired = self.session_mut().sched.take_retirements();
            let _ = self.send_retirements(&retired);
        }
        cancelled
    }

    /// Drop finished requests' bookkeeping (prompt and output buffers);
    /// their ids stop being pollable. Long-running submit/step servers
    /// should call this after consuming outputs — otherwise completed
    /// entries accumulate for the session's lifetime. The `serve` driver
    /// does it automatically.
    pub fn clear_finished(&mut self) {
        if let Some(s) = &mut self.session {
            s.sched.clear_finished();
        }
    }

    /// Step until the session is idle, then take its metrics (wire delta
    /// and KV-budget report included). Finished requests stay pollable
    /// until the next `begin_session`.
    pub fn drain(&mut self) -> Result<ServeMetrics> {
        loop {
            if self.step()?.idle {
                break;
            }
        }
        let wire = self.wire_stats();
        let s = self.session_mut();
        let mut m = std::mem::take(&mut s.metrics);
        m.record_wire(&wire.delta_since(&s.wire_baseline));
        m.set_kv_budget(s.budget_blocks, s.budget_bytes);
        m.publish_registry();
        s.wire_baseline = wire;
        Ok(m)
    }

    // ---- typed wire error plane -------------------------------------------

    /// Declare worker `wi` dead: bump the `failover.*` detection metrics,
    /// drop a timeline marker, and build the typed error [`Self::step`]
    /// catches for recovery. `since` is when the failing operation began
    /// (detection latency = now − since).
    fn declare_dead(&self, wi: usize, cause: DeathCause, since: Instant) -> anyhow::Error {
        crate::metrics::note_worker_death(since.elapsed().as_secs_f64());
        obs::instant(
            "failover",
            "worker-dead",
            vec![
                ("worker", obs::ArgVal::I(wi as i64)),
                ("cause", obs::ArgVal::S(cause.name().to_string())),
            ],
        );
        anyhow::Error::new(WorkerDeath { worker: wi, cause })
    }

    /// One receive from worker `wi` under the health policy's
    /// deadline/retry ladder. A healthy message resets the worker's
    /// strikes; expiries escalate through [`Verdict::Retry`] (counted in
    /// `failover.retries`) to a `Hang` death; fatal link errors and
    /// `WorkerError` reports declare death immediately.
    fn recv_worker(&self, wi: usize) -> Result<WireMsg> {
        let worker = &self.workers[wi];
        let policy = &self.opts.health;
        let t0 = Instant::now();
        loop {
            let attempt = worker.health.borrow().strikes();
            match worker.link.recv_timeout(policy.attempt_deadline(attempt)) {
                Ok(Some(WireMsg::WorkerError { msg })) => {
                    return Err(self.declare_dead(wi, DeathCause::Protocol(msg), t0));
                }
                Ok(Some(msg)) => {
                    worker.health.borrow_mut().on_alive();
                    return Ok(msg);
                }
                Ok(None) => match worker.health.borrow_mut().on_timeout(policy) {
                    Verdict::Retry(_) => crate::metrics::note_failover_retry(),
                    Verdict::Dead => {
                        return Err(self.declare_dead(wi, DeathCause::Hang, t0));
                    }
                },
                Err(e) => {
                    return Err(self.declare_dead(wi, DeathCause::of_transport(&e), t0));
                }
            }
        }
    }

    /// Send to worker `wi`; a failed send IS a death (the link is gone or
    /// unusable — sends have no retry ladder).
    fn send_to(&self, wi: usize, msg: WireMsg) -> Result<()> {
        self.workers[wi]
            .link
            .send(msg)
            .map_err(|e| self.declare_dead(wi, DeathCause::of_transport(&e), Instant::now()))
    }

    /// Queue a frame into worker `wi`'s pending batch envelope (delivered
    /// by the next [`Self::flush_all`] or plain send — the tcp transport
    /// turns a step's whole burst into one `writev`). Same death
    /// semantics as [`Self::send_to`].
    fn send_buffered_to(&self, wi: usize, msg: WireMsg) -> Result<()> {
        self.workers[wi]
            .link
            .send_buffered(msg)
            .map_err(|e| self.declare_dead(wi, DeathCause::of_transport(&e), Instant::now()))
    }

    /// Flush every worker's pending batch envelope. Must run before any
    /// receive that waits on a buffered request — the receive helpers call
    /// it themselves.
    fn flush_all(&self) -> Result<()> {
        for (wi, w) in self.workers.iter().enumerate() {
            w.link
                .flush()
                .map_err(|e| self.declare_dead(wi, DeathCause::of_transport(&e), Instant::now()))?;
        }
        Ok(())
    }

    // ---- attention round-trip -------------------------------------------

    fn send_q(&self, layer: usize, slots: &[u32], q: &HostTensor, lens: &[i32],
              seq_bucket: usize) -> Result<()> {
        let _sp = obs::span("wire", "send_q").arg("layer", layer as i64);
        let mc = self.config();
        let group = mc.heads / mc.kv_heads;
        for (wi, r) in self.plan.iter().enumerate() {
            let qr = r.q_range(group);
            let qs = slice_heads(q, qr.start, qr.count);
            let msg = WireMsg::StepQ {
                layer,
                slots: slots.to_vec(),
                q: qs,
                lens: lens.to_vec(),
                seq_bucket,
                overlap: self.opts.overlap,
            };
            self.step_net_bytes.set(self.step_net_bytes.get() + msg.wire_bytes());
            self.send_buffered_to(wi, msg)?;
        }
        Ok(())
    }

    fn send_kv(&self, layer: usize, k: &HostTensor, v: &HostTensor) -> Result<()> {
        let _sp = obs::span("wire", "send_kv").arg("layer", layer as i64);
        for (wi, r) in self.plan.iter().enumerate() {
            let msg = WireMsg::StepKv {
                layer,
                k: slice_heads(k, r.start, r.count),
                v: slice_heads(v, r.start, r.count),
            };
            self.step_net_bytes.set(self.step_net_bytes.get() + msg.wire_bytes());
            self.send_buffered_to(wi, msg)?;
        }
        Ok(())
    }

    fn recv_attn(&self, layer: usize, bucket: usize) -> Result<HostTensor> {
        // the step's request burst rides per-worker batch envelopes;
        // nothing is on the wire until this flush
        self.flush_all()?;
        let _sp = obs::span("wire", "recv_attn")
            .arg("layer", layer as i64)
            .arg("workers", self.workers.len() as i64);
        let mc = self.config();
        let w = self.workers.len();
        let group = mc.heads / mc.kv_heads;
        let hd = mc.head_dim;
        let mut shards: Vec<Option<HostTensor>> = (0..w).map(|_| None).collect();
        if w > 1 && self.mux_ready() {
            self.recv_attn_mux(layer, &mut shards)?;
        } else {
            for (wi, slot) in shards.iter_mut().enumerate() {
                *slot = Some(self.recv_attn_one(wi, layer)?);
            }
        }
        if w == 1 {
            // single shard IS the full [bucket, H, hd] output — zero-copy.
            // take() is infallible: both receive paths filled every slot.
            return Ok(shards[0].take().expect("one shard received"));
        }
        // interleave head shards back into [bucket, H, hd] at each
        // worker's query-range offset (ranges may be non-uniform)
        let mut out = vec![0.0f32; bucket * mc.heads * hd];
        for (wi, shard) in shards.iter().enumerate() {
            let shard = shard.as_ref().expect("every shard received");
            let qr = self.plan[wi].q_range(group);
            let sd = shard.as_f32();
            for b in 0..bucket {
                let dst = (b * mc.heads + qr.start) * hd;
                let src = b * qr.count * hd;
                out[dst..dst + qr.count * hd].copy_from_slice(&sd[src..src + qr.count * hd]);
            }
        }
        copies::add(bucket * mc.heads * hd * 4);
        Ok(HostTensor::f32(vec![bucket, mc.heads, hd], out))
    }

    /// Blocking receive of worker `wi`'s `AttnOut` for `layer` through the
    /// health ladder (the sequential path; also the only path for inproc
    /// links, which have no pollable fd).
    fn recv_attn_one(&self, wi: usize, layer: usize) -> Result<HostTensor> {
        let t0 = Instant::now();
        match self.recv_worker(wi)? {
            WireMsg::AttnOut { layer: l, out: shard } => {
                if l != layer {
                    // protocol desync: the link is unusable, treat as death
                    return Err(self.declare_dead(
                        wi,
                        DeathCause::Protocol(format!(
                            "attention out for layer {l}, expected {layer}"
                        )),
                        Instant::now(),
                    ));
                }
                self.note_turnaround(wi, layer, t0);
                Ok(shard)
            }
            other => Err(self.declare_dead(
                wi,
                DeathCause::Protocol(format!("unexpected reply {other:?}")),
                Instant::now(),
            )),
        }
    }

    /// Whether every worker link exposes a pollable fd (remote tcp links
    /// do; inproc links don't) and the platform has `poll(2)`.
    fn mux_ready(&self) -> bool {
        crate::net::mux::supported() && self.workers.iter().all(|w| w.link.poll_fd().is_some())
    }

    /// One bounded attempt to pull worker `wi`'s `AttnOut` for `layer`.
    /// `Ok(None)` means the deadline expired with no frame — NOT a strike;
    /// the mux loop owns the per-worker deadline ladder. Everything else a
    /// receive can surface is terminal here: `WorkerError`, a wrong-layer
    /// or off-protocol reply, and link errors all declare death.
    fn try_recv_attn(
        &self,
        wi: usize,
        layer: usize,
        timeout: Duration,
        t0: Instant,
    ) -> Result<Option<HostTensor>> {
        let worker = &self.workers[wi];
        match worker.link.recv_timeout(timeout) {
            Ok(Some(WireMsg::AttnOut { layer: l, out })) if l == layer => {
                worker.health.borrow_mut().on_alive();
                self.note_turnaround(wi, layer, t0);
                Ok(Some(out))
            }
            Ok(Some(WireMsg::AttnOut { layer: l, .. })) => Err(self.declare_dead(
                wi,
                DeathCause::Protocol(format!("attention out for layer {l}, expected {layer}")),
                t0,
            )),
            Ok(Some(WireMsg::WorkerError { msg })) => {
                Err(self.declare_dead(wi, DeathCause::Protocol(msg), t0))
            }
            Ok(Some(other)) => Err(self.declare_dead(
                wi,
                DeathCause::Protocol(format!("unexpected reply {other:?}")),
                t0,
            )),
            Ok(None) => Ok(None),
            Err(e) => Err(self.declare_dead(wi, DeathCause::of_transport(&e), t0)),
        }
    }

    /// Multiplexed attention gather: wait on every outstanding worker
    /// socket at once with `poll(2)` instead of draining them in index
    /// order, so one slow shard can't serialize behind the others.
    ///
    /// Loop shape, in order:
    /// 1. zero-timeout sweep — frames already sitting in userspace read
    ///    buffers (a prior read pulled several envelopes) are invisible to
    ///    `poll`, so every outstanding link gets a free non-blocking try;
    /// 2. `poll` the survivors until the *nearest* per-worker deadline;
    /// 3. service readable links with a short bounded receive;
    /// 4. on a poll round with nothing readable, strike every expired
    ///    worker through the same [`Verdict`] ladder `recv_worker` runs
    ///    (Retry re-arms that worker's deadline; Dead is a `Hang` death).
    fn recv_attn_mux(&self, layer: usize, shards: &mut [Option<HostTensor>]) -> Result<()> {
        let policy = &self.opts.health;
        let t0 = Instant::now();
        let mut outstanding: Vec<usize> = (0..shards.len()).collect();
        let mut deadlines: Vec<Instant> = self
            .workers
            .iter()
            .map(|w| t0 + policy.attempt_deadline(w.health.borrow().strikes()))
            .collect();
        while !outstanding.is_empty() {
            let mut i = 0;
            while i < outstanding.len() {
                let wi = outstanding[i];
                if let Some(out) = self.try_recv_attn(wi, layer, Duration::ZERO, t0)? {
                    shards[wi] = Some(out);
                    outstanding.swap_remove(i);
                } else {
                    i += 1;
                }
            }
            if outstanding.is_empty() {
                break;
            }
            let now = Instant::now();
            let wait = outstanding
                .iter()
                .map(|&wi| deadlines[wi].saturating_duration_since(now))
                .min()
                .unwrap_or(Duration::ZERO);
            let fds: Vec<i32> = outstanding
                .iter()
                .map(|&wi| self.workers[wi].link.poll_fd().expect("mux_ready checked"))
                .collect();
            let ready = crate::net::mux::wait_readable(&fds, wait)
                .map_err(|e| anyhow!("mux poll failed: {e}"))?;
            if ready.is_empty() {
                let now = Instant::now();
                for &wi in &outstanding {
                    if now < deadlines[wi] {
                        continue;
                    }
                    match self.workers[wi].health.borrow_mut().on_timeout(policy) {
                        Verdict::Retry(attempt) => {
                            crate::metrics::note_failover_retry();
                            deadlines[wi] = now + policy.attempt_deadline(attempt);
                        }
                        Verdict::Dead => {
                            return Err(self.declare_dead(wi, DeathCause::Hang, t0));
                        }
                    }
                }
                continue;
            }
            // resolve ready entries to worker ids BEFORE mutating
            // `outstanding` — `ready` indexes the fds snapshot above
            let ready_wi: Vec<usize> = ready.iter().map(|&ri| outstanding[ri]).collect();
            for wi in ready_wi {
                if let Some(out) =
                    self.try_recv_attn(wi, layer, Duration::from_millis(1), t0)?
                {
                    shards[wi] = Some(out);
                    outstanding.retain(|&o| o != wi);
                }
            }
        }
        Ok(())
    }

    /// Per-worker reply turnaround (receive-entry → `AttnOut` in hand):
    /// a trace instant on the wire track plus the
    /// `net.attn_turnaround_ns` histogram.
    fn note_turnaround(&self, wi: usize, layer: usize, t0: Instant) {
        use std::sync::OnceLock;
        static H: OnceLock<obs::Histogram> = OnceLock::new();
        let ns = t0.elapsed().as_nanos() as u64;
        H.get_or_init(|| obs::registry().histogram("net.attn_turnaround_ns")).record(ns);
        if obs::trace::enabled() {
            obs::instant(
                "wire",
                "attn_turnaround",
                vec![
                    ("worker", obs::ArgVal::I(wi as i64)),
                    ("layer", obs::ArgVal::I(layer as i64)),
                    ("ns", obs::ArgVal::I(ns as i64)),
                ],
            );
        }
    }

    // ---- KV lifecycle control plane ---------------------------------------

    /// Free `slot`'s KV blocks on every attention worker (request retired).
    fn retire_slot(&self, slot: u32) -> Result<()> {
        let _sp = obs::span("wire", "retire").arg("slot", slot as i64);
        for wi in 0..self.workers.len() {
            self.send_buffered_to(wi, WireMsg::Retire { slot })?;
        }
        Ok(())
    }

    /// Map the first `tokens` of `src_slot`'s KV into `dst_slot` on every
    /// attention worker (refcounted prefix sharing — slot-relative, so one
    /// message fits all workers despite per-worker block ids).
    fn map_blocks(&self, dst_slot: u32, src_slot: u32, tokens: usize) -> Result<()> {
        let _sp = obs::span("wire", "map_blocks")
            .arg("dst", dst_slot as i64)
            .arg("src", src_slot as i64)
            .arg("tokens", tokens as i64);
        for wi in 0..self.workers.len() {
            self.send_buffered_to(wi, WireMsg::MapBlocks { slot: dst_slot, src_slot, tokens })?;
        }
        Ok(())
    }

    /// Pool-wide KV-arena snapshot: polls every worker and sums the
    /// per-shard stats (block counts add across shards; the byte size of a
    /// block shrinks with the shard width). Replies carrying a stale
    /// membership epoch — queued before a reshard's re-`Welcome` — are
    /// discarded and the link re-read, so a snapshot can never mix
    /// geometries.
    pub fn kv_stats(&self) -> Result<KvCacheStats> {
        let _sp = obs::span("wire", "kv_stats");
        for wi in 0..self.workers.len() {
            self.send_to(wi, WireMsg::KvStatsReq)?;
        }
        let mut sum = KvCacheStats::default();
        for wi in 0..self.workers.len() {
            loop {
                match self.recv_worker(wi)? {
                    WireMsg::KvStats { stats, epoch } if epoch == self.epoch => {
                        sum = sum.merge(&stats);
                        break;
                    }
                    // stale-epoch snapshot: fenced off, keep reading
                    WireMsg::KvStats { .. } => {}
                    other => {
                        return Err(self.declare_dead(
                            wi,
                            DeathCause::Protocol(format!("unexpected reply {other:?}")),
                            Instant::now(),
                        ));
                    }
                }
            }
        }
        Ok(sum)
    }

    // ---- one decode iteration for one batch group -------------------------

    /// Execute one full decode step for the given batch rows (the
    /// scheduler's plan). Returns the next token per row and the step's
    /// breakdown.
    fn decode_step_rows(&self, rows: &[DecodeRow]) -> Result<(Vec<i32>, StepBreakdown)> {
        let mc = self.config();
        let step_t0 = Instant::now();
        self.step_net_bytes.set(0);
        let b = rows.len();
        let bucket = self
            .engine
            .manifest
            .batch_bucket(b)
            .ok_or_else(|| anyhow!("batch {b} exceeds largest bucket"))?;

        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        let mut lens = vec![0i32; bucket];
        let mut slots = vec![PAD_SLOT; bucket];
        let mut max_len_after = 1usize;
        for (i, r) in rows.iter().enumerate() {
            tokens[i] = r.input;
            pos[i] = r.len;
            lens[i] = r.len;
            slots[i] = r.slot;
            max_len_after = max_len_after.max(r.len as usize + 1);
        }
        let seq_bucket = self
            .engine
            .manifest
            .seq_bucket(max_len_after)
            .ok_or_else(|| anyhow!("context {max_len_after} exceeds max seq bucket"))?;

        let _sp_decode = obs::span("leader", "decode-step")
            .arg("rows", b as i64)
            .arg("bucket", bucket as i64)
            .arg("seq_bucket", seq_bucket as i64);
        if self.opts.step_trace && obs::trace::enabled() {
            let ids: Vec<RequestId> = rows.iter().map(|r| r.id).collect();
            obs::instant(
                "leader",
                "step-trace",
                vec![
                    ("reqs", obs::ArgVal::S(format!("{ids:?}"))),
                    ("slots", obs::ArgVal::S(format!("{slots:?}"))),
                    ("lens", obs::ArgVal::S(format!("{lens:?}"))),
                    ("bucket", obs::ArgVal::I(bucket as i64)),
                    ("seq_bucket", obs::ArgVal::I(seq_bucket as i64)),
                ],
            );
        }

        let tokens_t = HostTensor::i32(vec![bucket], tokens);
        let pos_t = HostTensor::i32(vec![bucket], pos);

        let mut model_s = 0.0;
        let mut attn_wait_s = 0.0;

        // slice_first
        let t0 = Instant::now();
        let sp = obs::span("leader", "slice_first");
        let mut outs = self.engine.execute(
            "slice_first",
            bucket,
            None,
            &[&tokens_t, &pos_t],
            &first_weight_names(),
        )?;
        drop(sp);
        model_s += t0.elapsed().as_secs_f64();
        let (mut q, mut k, mut v, mut resid) = take4(&mut outs)?;

        for layer in 0..mc.layers {
            // ship q early, then k/v (the §4.2.2 ordering)
            self.send_q(layer, &slots, &q, &lens, seq_bucket)?;
            self.send_kv(layer, &k, &v)?;
            let t1 = Instant::now();
            let attn_out = self.recv_attn(layer, bucket)?;
            attn_wait_s += t1.elapsed().as_secs_f64();

            let t2 = Instant::now();
            if layer + 1 < mc.layers {
                let sp = obs::span("leader", "slice_mid").arg("layer", layer as i64);
                let mut outs = self.engine.execute(
                    "slice_mid",
                    bucket,
                    None,
                    &[&attn_out, &resid, &pos_t],
                    &mid_weight_names(layer),
                )?;
                drop(sp);
                model_s += t2.elapsed().as_secs_f64();
                let (q2, k2, v2, r2) = take4(&mut outs)?;
                q = q2;
                k = k2;
                v = v2;
                resid = r2;
            } else {
                let sp = obs::span("leader", "slice_last").arg("layer", layer as i64);
                let outs = self.engine.execute(
                    "slice_last",
                    bucket,
                    None,
                    &[&attn_out, &resid],
                    &last_weight_names(mc.layers),
                )?;
                drop(sp);
                model_s += t2.elapsed().as_secs_f64();
                let next = outs
                    .into_iter()
                    .nth(1)
                    .ok_or_else(|| anyhow!("slice_last output arity"))?;
                let total = step_t0.elapsed().as_secs_f64();
                let net_bytes = self.step_net_bytes.get();
                let net_model_s = (self.opts.stack.fixed_overhead()
                    + net_bytes as f64 / (LINE_RATE_400G * self.opts.stack.bw_efficiency))
                    * self.opts.time_scale.min(1.0);
                let bd = StepBreakdown {
                    model_s,
                    attn_s: attn_wait_s,
                    network_s: net_model_s,
                    sched_s: (total - model_s - attn_wait_s - net_model_s).max(0.0),
                    total_s: total,
                };
                let sp_sample = obs::span("leader", "sample").arg("rows", b as i64);
                let mut next_tokens = next.as_i32()[..bucket].to_vec();
                next_tokens.truncate(b.max(1));
                drop(sp_sample);
                return Ok((next_tokens, bd));
            }
        }
        unreachable!("loop returns at last layer");
    }

    // ---- chunked prefill (paper §5) ---------------------------------------

    /// Execute ONE chunked-prefill pass for `slot`: `chunk` holds prompt
    /// tokens for positions `cached..cached+chunk.len()`. Returns the
    /// model's next-token prediction after the chunk's last valid row (the
    /// request's first generated token once the final chunk lands). The KV
    /// lands on the attention workers layer-by-layer exactly as the
    /// paper's transition protocol streams it.
    fn exec_prefill_chunk(&self, slot: u32, chunk: &[i32], cached: usize) -> Result<i32> {
        let _sp = obs::span("leader", "prefill-chunk")
            .arg("slot", slot as i64)
            .arg("cached", cached as i64)
            .arg("valid", chunk.len() as i64);
        let mc = self.config().clone();
        let valid = chunk.len();
        assert!(valid > 0, "empty prefill chunk");
        let bucket = self
            .engine
            .manifest
            .batch_bucket(valid)
            .ok_or_else(|| anyhow!("chunk exceeds buckets"))?;
        let seq_bucket = self
            .engine
            .manifest
            .seq_bucket(cached + bucket)
            .ok_or_else(|| anyhow!("prompt exceeds context window"))?;

        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        for i in 0..valid {
            tokens[i] = chunk[i];
            pos[i] = (cached + i) as i32;
        }
        for (i, p) in pos.iter_mut().enumerate().skip(valid) {
            *p = (cached + i) as i32; // padding rows: harmless positions
        }
        let tokens_t = HostTensor::i32(vec![bucket], tokens);
        let pos_t = HostTensor::i32(vec![bucket], pos);

        let mut outs = self.engine.execute(
            "slice_first",
            bucket,
            None,
            &[&tokens_t, &pos_t],
            &first_weight_names(),
        )?;
        let (mut q, mut k, mut v, mut resid) = take4(&mut outs)?;
        let mut next_token = 0i32;

        for layer in 0..mc.layers {
            self.send_prefill(layer, slot, &q, &k, &v, cached as i32, valid, seq_bucket)?;
            let attn_out = self.recv_attn(layer, bucket)?;
            if layer + 1 < mc.layers {
                let mut outs = self.engine.execute(
                    "slice_mid",
                    bucket,
                    None,
                    &[&attn_out, &resid, &pos_t],
                    &mid_weight_names(layer),
                )?;
                let (q2, k2, v2, r2) = take4(&mut outs)?;
                q = q2;
                k = k2;
                v = v2;
                resid = r2;
            } else {
                let outs = self.engine.execute(
                    "slice_last",
                    bucket,
                    None,
                    &[&attn_out, &resid],
                    &last_weight_names(mc.layers),
                )?;
                let next = &outs[1];
                next_token = next.as_i32()[valid - 1];
            }
        }
        Ok(next_token)
    }

    /// Prefill `prompt` into cache slot `slot` in chunks of the largest
    /// batch bucket, returning the first generated token. Low-level: the
    /// engine normally drives prefill chunk-by-chunk through `step`; this
    /// whole-prompt form is the KV *rebuild* path (worker recovery replays
    /// known token history through it — see [`Self::recover_attn_worker`]).
    pub fn prefill(&self, slot: u32, prompt: &[i32]) -> Result<i32> {
        assert!(!prompt.is_empty());
        let chunk = self.max_batch_bucket()?;
        let mut cached = 0usize;
        let mut next_token = 0i32;
        while cached < prompt.len() {
            let take = (prompt.len() - cached).min(chunk);
            next_token = self.exec_prefill_chunk(slot, &prompt[cached..cached + take], cached)?;
            cached += take;
        }
        Ok(next_token)
    }

    /// Largest batch bucket: the chunked-prefill chunk size.
    fn max_batch_bucket(&self) -> Result<usize> {
        self.engine
            .manifest
            .batch_buckets
            .iter()
            .copied()
            .max()
            .ok_or_else(|| anyhow!("no batch buckets"))
    }

    /// Pool-wide wire-traffic accounting: per-message-class logical
    /// (`wire_bytes()` model) and measured serialized bytes, summed over
    /// every leader-side link endpoint since pipeline start. Serialized
    /// bytes are only non-zero on serializing transports (`tcp`).
    pub fn wire_stats(&self) -> crate::net::WireStats {
        let mut sum = self.retired_wire;
        for worker in &self.workers {
            sum.merge(&worker.link.stats());
        }
        sum
    }

    /// The transport this pipeline was started with.
    pub fn transport(&self) -> TransportKind {
        self.opts.transport
    }

    /// The attention backend the workers were started with.
    pub fn attn_backend(&self) -> AttnBackendKind {
        self.opts.attn_backend
    }

    /// The KV block storage dtype the workers' arenas run.
    pub fn kv_dtype(&self) -> KvDtype {
        self.opts.kv_dtype
    }

    /// The admission policy the scheduler runs.
    pub fn admission(&self) -> AdmissionKind {
        self.opts.admission
    }

    #[allow(clippy::too_many_arguments)]
    fn send_prefill(
        &self,
        layer: usize,
        slot: u32,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        cached: i32,
        valid: usize,
        seq_bucket: usize,
    ) -> Result<()> {
        let _sp = obs::span("wire", "send_prefill")
            .arg("layer", layer as i64)
            .arg("slot", slot as i64);
        let mc = self.config();
        let group = mc.heads / mc.kv_heads;
        for (wi, r) in self.plan.iter().enumerate() {
            let qr = r.q_range(group);
            let msg = WireMsg::PrefillChunk {
                layer,
                slot,
                q: slice_heads(q, qr.start, qr.count),
                k: slice_heads(k, r.start, r.count),
                v: slice_heads(v, r.start, r.count),
                cached,
                valid,
                seq_bucket,
            };
            self.step_net_bytes.set(self.step_net_bytes.get() + msg.wire_bytes());
            self.send_buffered_to(wi, msg)?;
        }
        Ok(())
    }

    // ---- driver loops over the request-lifecycle API ----------------------

    /// Greedy-decode `steps` tokens for each prompt with the golden
    /// teacher-forced semantics (prompts feed through the decode path).
    /// A driver loop: submit every prompt, drain, collect outputs. Bit-
    /// identical to the historical wave-bound `decode` for any batch that
    /// fits one group (per-request tokens are batch-invariant, so larger
    /// batches queue instead of erroring).
    pub fn decode(&mut self, prompts: &[Vec<i32>], steps: usize) -> Result<Vec<Vec<i32>>> {
        let waves = self.opts.max_waves;
        self.begin_session(GroupMode::Packed, waves)?;
        let mut ids = Vec::with_capacity(prompts.len());
        for p in prompts {
            match self.submit_with_mode(p.clone(), steps, false) {
                Ok(id) => ids.push(id),
                Err(e) => {
                    // roll the partial batch back: leaving queued requests
                    // behind would wedge the next begin_session
                    for &id in &ids {
                        self.cancel(id);
                    }
                    return Err(anyhow!("decode: {e}"));
                }
            }
        }
        self.drain()?;
        Ok(ids
            .iter()
            .map(|&id| self.poll(id).expect("just submitted").tokens)
            .collect())
    }

    /// Prefill-then-decode for one prompt: chunked prefill populates the
    /// KV cache, then `steps` tokens are greedy-decoded. Must produce
    /// exactly the same tokens as the teacher-forced `decode` path
    /// (asserted in tests). The engine picks the slot.
    pub fn generate(&mut self, prompt: &[i32], steps: usize) -> Result<Vec<i32>> {
        let waves = self.opts.max_waves;
        self.begin_session(GroupMode::Packed, waves)?;
        let id = self
            .submit_with_mode(prompt.to_vec(), steps, true)
            .map_err(|e| anyhow!("generate: {e}"))?;
        self.drain()?;
        Ok(self.poll(id).expect("just submitted").tokens)
    }

    /// Serve a request list with continuous batching: a thin driver loop
    /// over submit/step/drain (kept for the CLI and metrics report).
    /// Requests use synthetic prompts of the declared lengths (the traces
    /// carry lengths only, like the paper's). `waves` only scales the
    /// engine's live-request capacity to `slots × waves`; batch
    /// composition is iteration-granular regardless. Invalid requests are
    /// rejected individually (`ServeMetrics::rejected_submissions`) — the
    /// run no longer aborts.
    pub fn serve(&mut self, requests: &[Request], waves: usize) -> Result<ServeMetrics> {
        self.serve_with(requests, waves, GroupMode::Packed)
    }

    /// The legacy wave-partitioned driver: same engine, same admission,
    /// but decode groups follow the physical slot ranges (wave `w` = slots
    /// `[w·slots, (w+1)·slots)`), so half-empty waves step alone exactly
    /// like the old wave-bound loop. Survives only for comparison — the
    /// `e2e/continuous-batching` bench rows measure what iteration-level
    /// repacking buys over it.
    pub fn serve_waves(&mut self, requests: &[Request], waves: usize) -> Result<ServeMetrics> {
        self.serve_with(requests, waves, GroupMode::ByWave)
    }

    fn serve_with(
        &mut self,
        requests: &[Request],
        waves: usize,
        grouping: GroupMode,
    ) -> Result<ServeMetrics> {
        let vocab = self.config().vocab;
        self.begin_session(grouping, waves)?;
        let prompts = crate::trace::synth_prompts(requests, vocab, SERVE_PROMPT_SEED);
        for (r, prompt) in requests.iter().zip(prompts) {
            if let Err(e) = self.submit(prompt, r.gen_tokens) {
                eprintln!("serve: rejecting request {}: {e}", r.id);
                self.session_mut().metrics.record_rejection();
            }
        }
        let m = self.drain()?;
        // cap per-request bookkeeping; a fresh driver run repolls nothing
        self.clear_finished();
        Ok(m)
    }

    // ---- fault tolerance (paper §5) ---------------------------------------

    /// Live recovery from a declared worker death, run inside [`Self::step`]:
    ///
    /// 1. **Preempt** every live request through the scheduler's
    ///    promoted-token replay — its KV head-shard on the dead worker is
    ///    gone, so its context must re-prefill (effective prompt = prompt
    ///    ⧺ generated-so-far).
    /// 2. **Replace or shrink.** With `allow_respawn` (the default) a
    ///    replacement worker is spawned and handshaked at the same width.
    ///    With `--no-respawn` the pool **degrades**: the dead member is
    ///    dropped and the survivors keep serving at W−1 — unless that
    ///    falls below the `min_workers` floor, in which case the queued
    ///    retirements are flushed to the survivors (zero leaked blocks)
    ///    and a typed, non-recoverable [`MembershipRefused`] surfaces.
    /// 3. **Epoch-fenced reshard**: bump the epoch, re-plan head ranges
    ///    over the current members, re-`Welcome` everyone (arena rebuild =
    ///    implicit retire-everything), and run the fenced `KvStatsReq`
    ///    barrier that discards any reply from the dead geometry.
    ///
    /// Decoding resumes through the normal admission path on subsequent
    /// steps; the recovered — or degraded — output is bit-identical to an
    /// unfailed run on the native backend (chaos suite + `fault-smoke`).
    /// Returns the preempted ids.
    fn recover_from_death(&mut self, idx: usize, cause: &DeathCause) -> Result<Vec<RequestId>> {
        let t0 = Instant::now();
        let degrade = !self.opts.allow_respawn;
        let _sp = obs::span("failover", if degrade { "degrade" } else { "recover" })
            .arg("worker", idx as i64)
            .arg_str("cause", cause.name());
        // (1) preempt every live request
        let (live, tokens_replayed) = self.preempt_all_live();
        // (2) replace the dead worker, or shrink the pool
        if degrade {
            let survivors = self.workers.len() - 1;
            let policy =
                MembershipPolicy { allow_respawn: false, min_workers: self.opts.min_workers };
            if !policy.can_degrade_to(survivors) {
                // refuse below the floor. Flush the preempt-queued Retires
                // to the survivors directly (the dead link would poison
                // send_retirements) so their arenas stay leak-free, then
                // fail typed; the pool is left as-is and every later step
                // surfaces the same refusal.
                let retires = self.session_mut().sched.take_retirements();
                for &(_, slot) in &retires {
                    for (wi, w) in self.workers.iter().enumerate() {
                        if wi == idx {
                            continue;
                        }
                        let _ = w.link.send(WireMsg::Retire { slot });
                    }
                }
                return Err(anyhow::Error::new(MembershipRefused {
                    survivors,
                    floor: self.opts.min_workers.max(1),
                    cause: cause.clone(),
                }));
            }
            // drop the dead handle (wire counters folded into the pool
            // totals); its thread exits on its own once it observes the
            // severed link
            self.retired_wire.merge(&self.workers[idx].link.stats());
            let _dead = self.workers.remove(idx);
        } else {
            self.retired_wire.merge(&self.workers[idx].link.stats());
            let geom = ModelGeom::of(self.config());
            // the old handle is dropped without a join: its thread exits on
            // its own once it observes the severed link (a *hung* thread
            // would otherwise block recovery here)
            self.workers[idx] = spawn_worker(&self.opts, geom, idx, true)?;
            self.handshake_hello(idx)?;
        }
        // (3) epoch-fenced reshard over the current membership
        let snap = self.reshard_and_barrier()?;
        self.rebudget(snap);
        let s = self.session_mut();
        s.metrics.record_recovery(tokens_replayed, t0.elapsed().as_secs_f64());
        if degrade {
            crate::metrics::note_degrade(t0.elapsed().as_secs_f64());
        }
        Ok(live)
    }

    /// Preempt every live request through the promoted-token replay —
    /// reverse running order so front-of-queue insertion re-admits in the
    /// original order. Slots are captured first: a request whose FIRST
    /// prefill chunk was in flight when the worker died shows no progress
    /// to the scheduler (wrote_kv = false, no Retire queued on preempt),
    /// yet surviving workers may have appended that chunk — retiring every
    /// preempted slot explicitly keeps their arenas leak-free (no-op where
    /// nothing landed). Returns the preempted ids and the total tokens
    /// their replays will re-prefill.
    fn preempt_all_live(&mut self) -> (Vec<RequestId>, u64) {
        let live = self.session_ref().sched.live_ids();
        {
            let s = self.session_mut();
            let slots: Vec<(RequestId, Option<u32>)> =
                live.iter().map(|&id| (id, s.sched.slot_of(id))).collect();
            for &id in live.iter().rev() {
                if let Some(ix) = s.prefix.as_mut() {
                    ix.remove(id);
                }
                s.sched.preempt(id);
            }
            let queued = s.sched.take_retirements();
            for &(id, slot) in &slots {
                let Some(slot) = slot else { continue };
                if !queued.iter().any(|&(_, qs)| qs == slot) {
                    s.sched.push_retirement(id, slot);
                }
            }
            for (id, slot) in queued {
                s.sched.push_retirement(id, slot);
            }
        }
        let mut tokens_replayed = 0u64;
        {
            let s = self.session_ref();
            for &id in &live {
                if let Some(p) = s.sched.effective_prompt(id) {
                    tokens_replayed += p.len() as u64;
                }
            }
        }
        (live, tokens_replayed)
    }

    /// Re-fence the pool on its **current** membership: bump the epoch,
    /// re-plan contiguous head ranges over the live workers, re-`Welcome`
    /// every member (the worker rebuilds its arena from the carried
    /// geometry — an implicit retire-everything), flush queued retirements
    /// (no-ops on fresh arenas, but keeps the scheduler's ledger drained),
    /// then run the epoch-fenced `KvStatsReq` barrier: per link, replies
    /// are discarded until one echoes the new epoch, so in-flight frames
    /// from the dead geometry can never alias into the new one. Callers
    /// must have preempted every live request first. Resets every
    /// survivor's health ladder and returns the fresh pool snapshot.
    fn reshard_and_barrier(&mut self) -> Result<KvCacheStats> {
        self.epoch += 1;
        let _sp = obs::span("failover", "reshard")
            .arg("epoch", self.epoch as i64)
            .arg("workers", self.workers.len() as i64);
        let kv_heads = self.config().kv_heads;
        self.plan = head_ranges(kv_heads, self.workers.len())
            .map_err(|e| anyhow!("reshard plan: {e}"))?;
        for wi in 0..self.workers.len() {
            let msg = self.welcome_msg(wi);
            self.send_to(wi, msg)?;
        }
        let retires = self.session_mut().sched.take_retirements();
        self.send_retirements(&retires)?;
        for wi in 0..self.workers.len() {
            self.send_to(wi, WireMsg::KvStatsReq)?;
        }
        let mut snap = KvCacheStats::default();
        for wi in 0..self.workers.len() {
            loop {
                match self.recv_worker(wi)? {
                    WireMsg::KvStats { stats, epoch } if epoch == self.epoch => {
                        snap = snap.merge(&stats);
                        break;
                    }
                    // pre-reshard traffic (stale-epoch stats, attention
                    // outputs of the abandoned iteration): fenced off
                    _stale => {}
                }
            }
        }
        // a later, unrelated death must face the full retry ladder again
        for w in &self.workers {
            w.health.borrow_mut().reset();
        }
        Ok(snap)
    }

    /// Re-derive the scheduler's byte ledger and the session budget view
    /// from a fresh pool snapshot: a reshard changes the per-worker block
    /// byte size (shards hold more or fewer heads), so block↔byte budget
    /// conversions must rebase or admission would misjudge capacity.
    fn rebudget(&mut self, snap: KvCacheStats) {
        let block_bytes =
            if snap.total_blocks > 0 { snap.total_bytes / snap.total_blocks } else { 0 };
        let budget = match (self.opts.kv_byte_budget, self.opts.kv_block_budget) {
            (Some(bytes), _) => KvBudget::Bytes(bytes),
            (None, Some(blocks)) => KvBudget::Blocks(blocks),
            (None, None) => KvBudget::Unlimited,
        };
        let (budget_blocks, budget_bytes) = match budget {
            KvBudget::Unlimited => (None, None),
            KvBudget::Blocks(b) => (Some(b), (block_bytes > 0).then_some(b * block_bytes)),
            KvBudget::Bytes(b) => ((block_bytes > 0).then(|| b / block_bytes), Some(b)),
        };
        let s = self.session_mut();
        if block_bytes > 0 {
            s.sched.set_block_bytes(block_bytes);
        }
        s.budget_blocks = budget_blocks;
        s.budget_bytes = budget_bytes;
        s.kv_snap = snap;
        s.metrics.record_kv(snap);
    }

    /// **Scale-up adoption**: spawn and handshake one additional attention
    /// worker, quiesce at the step boundary (every live request preempted
    /// through the promoted-token replay), and reshard W→W+1. Output is
    /// bit-identical to an un-adopted run on the native backend. The
    /// joining link IS fault-wrapped when the pipeline's `--fault-plan`
    /// targets its index (adoption is a first spawn, not a recovery
    /// respawn) — which is what lets tests kill a worker inside the
    /// adoption window. A failed adoption is non-fatal when the rollback
    /// reshard over the original members succeeds: the pool stays at W and
    /// the error is returned; if the rollback ALSO fails (a survivor died
    /// inside the window), the next [`Self::step`]'s recovery picks it up.
    pub fn adopt_worker(&mut self) -> Result<usize> {
        let t0 = Instant::now();
        if self.opts.attn_backend != AttnBackendKind::Native {
            bail!(
                "adoption requires --attn-backend native \
                 (engine attention artifacts are per-width)"
            );
        }
        let kv_heads = self.config().kv_heads;
        let new_idx = self.workers.len();
        if new_idx + 1 > kv_heads {
            bail!(
                "cannot adopt a {}th worker: only {} kv heads to shard",
                new_idx + 1,
                kv_heads
            );
        }
        let _sp = obs::span("failover", "adopt").arg("worker", new_idx as i64);
        let geom = ModelGeom::of(self.config());
        self.workers.push(spawn_worker(&self.opts, geom, new_idx, false)?);
        match self.adopt_inner(new_idx) {
            Ok(()) => {
                crate::metrics::note_adoption(t0.elapsed().as_secs_f64());
                Ok(new_idx)
            }
            Err(e) => {
                // roll back: drop the joiner and re-fence the original
                // members at their previous width (everything is already
                // preempted, so the replay machinery absorbs the churn
                // either way)
                let dead = self.workers.remove(new_idx);
                self.retired_wire.merge(&dead.link.stats());
                drop(dead);
                if let Ok(snap) = self.reshard_and_barrier() {
                    self.rebudget(snap);
                }
                Err(e)
            }
        }
    }

    fn adopt_inner(&mut self, new_idx: usize) -> Result<()> {
        self.handshake_hello(new_idx)?;
        // quiesce: adoption re-keys every worker's shard, so live KV is
        // rebuilt by replay exactly as in failure recovery
        let (_live, _tokens) = self.preempt_all_live();
        let snap = self.reshard_and_barrier()?;
        self.rebudget(snap);
        Ok(())
    }

    /// Deterministic chaos hook: sever worker `idx`'s link *now*. The
    /// leader-side endpoint is replaced with a dead stub (counters
    /// preserved) and the real link is dropped, so the worker thread
    /// observes the disconnect and exits. The next wire operation touching
    /// the worker surfaces a typed [`WorkerDeath`], which [`Self::step`]
    /// recovers from when `auto_recover` is on.
    pub fn inject_worker_death(&mut self, idx: usize) {
        let w = &mut self.workers[idx];
        let dead = DeadTransport::new(w.link.kind(), w.link.stats());
        w.link = Box::new(dead);
    }

    /// Simulate an attention-worker failure: its thread is terminated and
    /// all its KV state (the head shard of every live request) is lost.
    pub fn kill_attn_worker(&mut self, idx: usize) {
        let w = &mut self.workers[idx];
        let _ = w.link.send(WireMsg::Shutdown);
        if let Some(t) = w.thread.take() {
            let _ = t.join();
        }
    }

    /// Recover a failed attention worker: spawn a replacement with an empty
    /// cache, then rebuild the lost KV by re-running each live request's
    /// prompt + already-generated tokens (kept by the service front-end)
    /// through the chunked-prefill path. Prefill broadcasts to all workers;
    /// healthy shards are overwritten with byte-identical values, so the
    /// rebuild is idempotent.
    pub fn recover_attn_worker(
        &mut self,
        idx: usize,
        live: &[(u32, Vec<i32>)],
    ) -> Result<()> {
        // keep the failed link's traffic in the pool totals before the
        // handle (and its counters) is replaced
        self.retired_wire.merge(&self.workers[idx].link.stats());
        let geom = ModelGeom::of(self.config());
        self.workers[idx] = spawn_worker(&self.opts, geom, idx, true)?;
        // membership handshake at the unchanged plan/epoch: the survivors'
        // shards stay resident, so this is a same-geometry re-join, not a
        // reshard
        self.handshake_hello(idx)?;
        let msg = self.welcome_msg(idx);
        self.send_to(idx, msg)?;
        for (slot, tokens) in live {
            assert!(!tokens.is_empty());
            // re-prefill the full known token history; the final next-token
            // output is discarded (decode continues from the caller's state)
            let _ = self.prefill(*slot, tokens)?;
        }
        Ok(())
    }

    /// Stop every worker with a clean `Shutdown` frame and join the
    /// threads. Pending retirements — e.g. queued by a cancel or an abort
    /// path whose typed error cut the run short of the next step's flush —
    /// go out first, so arenas quiesce leak-free before teardown (leak
    /// assertions read `kv_stats` right before this).
    pub fn shutdown(mut self) {
        if let Some(s) = &mut self.session {
            for (_, slot) in s.sched.take_retirements() {
                for w in &self.workers {
                    let _ = w.link.send(WireMsg::Retire { slot });
                }
            }
        }
        for w in &self.workers {
            let _ = w.link.send(WireMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

/// Slice heads `[h0, h0+n)` out of `[B, H, hd]`. The full-range slice (the
/// single-worker steady state) is a zero-copy Arc view; a genuine shard
/// slice must interleave rows and is charged to [`copies`].
fn slice_heads(t: &HostTensor, h0: usize, n: usize) -> HostTensor {
    let shape = t.shape();
    assert_eq!(shape.len(), 3);
    let (b, h, hd) = (shape[0], shape[1], shape[2]);
    assert!(h0 + n <= h);
    if h0 == 0 && n == h {
        return t.clone();
    }
    let src = t.as_f32();
    let mut out = vec![0.0f32; b * n * hd];
    for bi in 0..b {
        let s = (bi * h + h0) * hd;
        let d = bi * n * hd;
        out[d..d + n * hd].copy_from_slice(&src[s..s + n * hd]);
    }
    copies::add(b * n * hd * 4);
    HostTensor::f32(vec![b, n, hd], out)
}

fn take4(outs: &mut Vec<HostTensor>) -> Result<(HostTensor, HostTensor, HostTensor, HostTensor)> {
    if outs.len() != 4 {
        bail!("expected 4 outputs, got {}", outs.len());
    }
    // infallible: the arity was just checked (engine outputs, not wire data)
    let r = outs.pop().unwrap();
    let v = outs.pop().unwrap();
    let k = outs.pop().unwrap();
    let q = outs.pop().unwrap();
    Ok((q, k, v, r))
}

fn first_weight_names() -> Vec<String> {
    ["embed", "layer0.attn_norm", "layer0.wq", "layer0.wk", "layer0.wv"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

fn mid_weight_names(layer: usize) -> Vec<String> {
    let i = layer;
    let j = layer + 1;
    vec![
        format!("layer{i}.wo"),
        format!("layer{i}.ffn_norm"),
        format!("layer{i}.w_gate"),
        format!("layer{i}.w_up"),
        format!("layer{i}.w_down"),
        format!("layer{j}.attn_norm"),
        format!("layer{j}.wq"),
        format!("layer{j}.wk"),
        format!("layer{j}.wv"),
    ]
}

fn last_weight_names(layers: usize) -> Vec<String> {
    let i = layers - 1;
    vec![
        format!("layer{i}.wo"),
        format!("layer{i}.ffn_norm"),
        format!("layer{i}.w_gate"),
        format!("layer{i}.w_up"),
        format!("layer{i}.w_down"),
        "final_norm".to_string(),
        "lm_head".to_string(),
    ]
}
