//! The model worker / leader: drives the disaggregated decode pipeline on
//! the real tiny model through PJRT — slices on this thread (the
//! "compute-optimised device"), attention on worker threads (the
//! "memory-optimised pool"), tensors crossing the simulated network.
//!
//! Supports the paper's §4.2.2 overlap (send Q early, partial attention on
//! the workers, combine on K/V arrival) and §4.3 two-wave staggered
//! pipelining (wave B's slices execute while wave A's attention is in
//! flight on the worker threads).

use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::kernels::AttnBackendKind;
use crate::kvcache::{kv_blocks_needed, KvDtype};
use crate::metrics::{KvCacheStats, ServeMetrics, StepBreakdown};
use crate::net::{inproc, tcp, Transport, TransportKind};
use crate::netsim::stack::{NetStackModel, LINE_RATE_400G};
use crate::runtime::engine::Engine;
use crate::runtime::host::{copies, HostTensor};
use crate::trace::Request;

use super::attn_worker::{run_attn_worker, AttnWorkerCfg, ModelGeom, PAD_SLOT};
use super::messages::WireMsg;

/// Pipeline options.
#[derive(Debug, Clone)]
pub struct PipelineOpts {
    pub artifacts_dir: std::path::PathBuf,
    /// Attention workers (head-level shards; must divide kv_heads).
    pub attn_workers: usize,
    /// §4.2.2 resource-utilisation overlapping.
    pub overlap: bool,
    pub stack: &'static NetStackModel,
    /// Network pacing factor (0 = functional only, 1 = modelled latencies).
    pub time_scale: f64,
    /// Batch slots (max concurrent requests per wave).
    pub slots: usize,
    /// Pre-compile every leader entry point at start (removes multi-ms
    /// lazy-compile spikes from the first requests' tail latency).
    pub warmup: bool,
    /// Maximum staggered waves `serve` may run (sizes the KV slot pools).
    pub max_waves: usize,
    /// Use the chunked-prefill path for prompts in `serve` (paper §5);
    /// otherwise prompts are teacher-forced through the decode path.
    pub use_prefill: bool,
    /// Token slots per KV block in the workers' paged arenas.
    pub kv_block_size: usize,
    /// Storage dtype of the workers' KV block buffers (`--kv-dtype`):
    /// f32 (default), f16, or int8 with per-block scales. A worker-local
    /// storage decision — the wire and the leader stay f32 — that
    /// halves/quarters per-step KV bytes read by the native backend and
    /// resident bytes per cached token (so a fixed `--kv-budget` holds
    /// proportionally more context; `ServeMetrics` reports the byte view).
    pub kv_dtype: KvDtype,
    /// Which wire the leader↔worker links run over (`--transport`).
    pub transport: TransportKind,
    /// Which compute backend the attention workers run (`--attn-backend`):
    /// `engine` (PJRT artifacts over gathered K/V) or `native` (pure-Rust
    /// block-table kernel reading the arena in place — zero per-step KV
    /// copies on the workers).
    pub attn_backend: AttnBackendKind,
    /// Per-worker KV block budget for admission control (`--kv-budget`).
    /// `None` = admit unconditionally (the arena grows on demand). With a
    /// budget, `serve` consults the workers' `KvStats` snapshot +
    /// `kv_blocks_needed` before admitting and defers requests that would
    /// overflow it (counted in `ServeMetrics::deferred_admissions`).
    pub kv_block_budget: Option<usize>,
}

impl PipelineOpts {
    pub fn new(artifacts_dir: impl Into<std::path::PathBuf>) -> Self {
        PipelineOpts {
            artifacts_dir: artifacts_dir.into(),
            attn_workers: 2,
            overlap: true,
            stack: &crate::netsim::stack::FHBN,
            time_scale: 0.0,
            slots: 8,
            warmup: true,
            max_waves: 2,
            use_prefill: true,
            kv_block_size: 16,
            kv_dtype: KvDtype::F32,
            transport: TransportKind::Inproc,
            attn_backend: AttnBackendKind::Engine,
            kv_block_budget: None,
        }
    }
}

struct WorkerHandle {
    link: Box<dyn Transport>,
    thread: Option<std::thread::JoinHandle<()>>,
}

/// Spawn one attention-worker thread connected over the configured
/// transport: a paced in-process channel, or a real TCP loopback socket
/// carrying serialized `net::codec` frames.
fn spawn_worker(opts: &PipelineOpts, geom: ModelGeom, idx: usize, respawn: bool) -> Result<WorkerHandle> {
    let cfg = AttnWorkerCfg {
        artifacts_dir: opts.artifacts_dir.clone(),
        shard: idx,
        n_shards: opts.attn_workers,
        // distinct physical slots for every wave's requests
        slots: opts.slots * opts.max_waves,
        kv_block_size: opts.kv_block_size,
        kv_dtype: opts.kv_dtype,
        backend: opts.attn_backend,
        // the leader always has a manifest; handing the geometry over keeps
        // native workers artifact-independent
        geom: Some(geom),
    };
    let name = if respawn { format!("lamina-attn-{idx}-r") } else { format!("lamina-attn-{idx}") };
    let builder = std::thread::Builder::new().name(name);
    match opts.transport {
        TransportKind::Inproc => {
            let (leader_end, worker_end) =
                inproc::pair(opts.stack, LINE_RATE_400G, opts.time_scale);
            let thread = builder
                .spawn(move || run_attn_worker(cfg, worker_end))
                .context("spawn attention worker")?;
            Ok(WorkerHandle { link: Box::new(leader_end), thread: Some(thread) })
        }
        TransportKind::Tcp => {
            let (leader_end, worker_end) = tcp::pair().context("tcp loopback pair")?;
            let thread = builder
                .spawn(move || run_attn_worker(cfg, worker_end))
                .context("spawn attention worker")?;
            Ok(WorkerHandle { link: Box::new(leader_end), thread: Some(thread) })
        }
    }
}

/// One wave's per-slot decode state.
#[derive(Debug, Clone)]
struct SlotState {
    /// Front-end request id; surfaced by `LAMINA_STEP_TRACE=1` step traces.
    request_id: u64,
    /// physical KV cache slot on the attention workers — stable for the
    /// request's lifetime (wave positions shift as requests retire).
    cache_slot: u32,
    /// prompt tokens not yet consumed (fed teacher-forcing through decode)
    pending_prompt: Vec<i32>,
    /// cached tokens so far
    len: i32,
    /// tokens generated so far (output)
    generated: Vec<i32>,
    gen_target: usize,
    next_input: i32,
    /// KV blocks (per worker) this request reserves at full context —
    /// admission-control bookkeeping; 0 outside `serve`.
    kv_reserved: usize,
}

impl SlotState {
    fn done(&self) -> bool {
        self.pending_prompt.is_empty() && self.generated.len() >= self.gen_target
    }
}

/// The disaggregated serving pipeline.
pub struct DisaggPipeline {
    engine: Engine,
    workers: Vec<WorkerHandle>,
    opts: PipelineOpts,
    /// network bytes sent per decode step (for breakdown accounting)
    step_net_bytes: std::cell::Cell<usize>,
    /// Wire counters of links whose workers were replaced (fault
    /// tolerance) — folded into `wire_stats` so pool totals survive
    /// recovery.
    retired_wire: crate::net::WireStats,
}

impl DisaggPipeline {
    /// Start the pipeline: loads the leader engine and spawns the attention
    /// worker threads (each builds its own engine).
    pub fn start(opts: PipelineOpts) -> Result<Self> {
        let engine = Engine::load(&opts.artifacts_dir)?;
        if opts.warmup {
            // compile only the leader-side entry points (slices); attention
            // artifacts belong to the workers' engines
            for e in &engine.manifest.entrypoints {
                if e.entry.starts_with("slice_") {
                    engine.execute_warm(&e.entry, e.batch, e.seq)?;
                }
            }
        }
        let mc = &engine.manifest.config;
        if mc.kv_heads % opts.attn_workers != 0 {
            bail!(
                "attention workers ({}) must divide kv heads ({}) for head-level partitioning",
                opts.attn_workers,
                mc.kv_heads
            );
        }
        // the native backend computes any shard width in pure Rust; only the
        // engine backend depends on per-width attention artifacts
        let shard_ok = opts.attn_backend == AttnBackendKind::Native
            || opts.attn_workers == 1
            || engine
                .manifest
                .entrypoints
                .iter()
                .any(|e| e.entry == format!("attention_w{}", opts.attn_workers));
        if !shard_ok {
            bail!("no attention artifacts for {} shards — re-run `make artifacts`",
                opts.attn_workers);
        }

        let geom = ModelGeom::of(mc);
        let mut workers = Vec::new();
        for w in 0..opts.attn_workers {
            workers.push(spawn_worker(&opts, geom, w, false)?);
        }
        Ok(DisaggPipeline {
            engine,
            workers,
            opts,
            step_net_bytes: std::cell::Cell::new(0),
            retired_wire: crate::net::WireStats::new(),
        })
    }

    pub fn config(&self) -> &crate::runtime::manifest::ModelCfg {
        &self.engine.manifest.config
    }

    pub fn engine_stats(&self) -> crate::runtime::engine::EngineStats {
        self.engine.snapshot_stats()
    }

    // ---- attention round-trip -------------------------------------------

    fn send_q(&self, layer: usize, slots: &[u32], q: &HostTensor, lens: &[i32],
              seq_bucket: usize) -> Result<()> {
        let mc = self.config();
        let w = self.workers.len();
        let hs = mc.heads / w;
        for (wi, worker) in self.workers.iter().enumerate() {
            let qs = slice_heads(q, wi * hs, hs);
            let msg = WireMsg::StepQ {
                layer,
                slots: slots.to_vec(),
                q: qs,
                lens: lens.to_vec(),
                seq_bucket,
                overlap: self.opts.overlap,
            };
            self.step_net_bytes.set(self.step_net_bytes.get() + msg.wire_bytes());
            worker.link.send(msg).map_err(|e| anyhow!(e))?;
        }
        Ok(())
    }

    fn send_kv(&self, layer: usize, k: &HostTensor, v: &HostTensor) -> Result<()> {
        let mc = self.config();
        let w = self.workers.len();
        let khs = mc.kv_heads / w;
        for (wi, worker) in self.workers.iter().enumerate() {
            let msg = WireMsg::StepKv {
                layer,
                k: slice_heads(k, wi * khs, khs),
                v: slice_heads(v, wi * khs, khs),
            };
            self.step_net_bytes.set(self.step_net_bytes.get() + msg.wire_bytes());
            worker.link.send(msg).map_err(|e| anyhow!(e))?;
        }
        Ok(())
    }

    fn recv_attn(&self, layer: usize, bucket: usize) -> Result<HostTensor> {
        let mc = self.config();
        let w = self.workers.len();
        let hs = mc.heads / w;
        let hd = mc.head_dim;
        let mut shards: Vec<HostTensor> = Vec::with_capacity(w);
        for (wi, worker) in self.workers.iter().enumerate() {
            let msg = worker.link.recv().map_err(|e| anyhow!(e))?;
            match msg {
                WireMsg::AttnOut { layer: l, out: shard } => {
                    if l != layer {
                        bail!("attention out for layer {l}, expected {layer}");
                    }
                    shards.push(shard);
                }
                WireMsg::WorkerError { msg } => bail!("attention worker {wi}: {msg}"),
                other => bail!("unexpected reply {other:?}"),
            }
        }
        if w == 1 {
            // single shard IS the full [bucket, H, hd] output — zero-copy
            return Ok(shards.pop().unwrap());
        }
        // interleave head shards back into [bucket, H, hd]
        let mut out = vec![0.0f32; bucket * mc.heads * hd];
        for (wi, shard) in shards.iter().enumerate() {
            let sd = shard.as_f32();
            for b in 0..bucket {
                let dst = (b * mc.heads + wi * hs) * hd;
                let src = b * hs * hd;
                out[dst..dst + hs * hd].copy_from_slice(&sd[src..src + hs * hd]);
            }
        }
        copies::add(bucket * mc.heads * hd * 4);
        Ok(HostTensor::f32(vec![bucket, mc.heads, hd], out))
    }

    // ---- KV lifecycle control plane ---------------------------------------

    /// Free `slot`'s KV blocks on every attention worker (request retired).
    fn retire_slot(&self, slot: u32) -> Result<()> {
        for worker in &self.workers {
            worker.link.send(WireMsg::Retire { slot }).map_err(|e| anyhow!(e))?;
        }
        Ok(())
    }

    /// Pool-wide KV-arena snapshot: polls every worker and sums the
    /// per-shard stats (block counts add across shards; the byte size of a
    /// block shrinks with the shard width).
    pub fn kv_stats(&self) -> Result<KvCacheStats> {
        for worker in &self.workers {
            worker.link.send(WireMsg::KvStatsReq).map_err(|e| anyhow!(e))?;
        }
        let mut sum = KvCacheStats::default();
        for (wi, worker) in self.workers.iter().enumerate() {
            let msg = worker.link.recv().map_err(|e| anyhow!(e))?;
            match msg {
                WireMsg::KvStats { stats } => sum = sum.merge(&stats),
                WireMsg::WorkerError { msg } => bail!("attention worker {wi}: {msg}"),
                other => bail!("unexpected reply {other:?}"),
            }
        }
        Ok(sum)
    }

    // ---- one decode step for one wave -----------------------------------

    /// Execute one full decode step for the given wave. Returns the next
    /// token per active row and the step's breakdown.
    fn decode_step(&self, wave: &mut [SlotState], active: &[usize]) -> Result<(Vec<i32>, StepBreakdown)> {
        let mc = self.config();
        let step_t0 = Instant::now();
        self.step_net_bytes.set(0);
        let b = active.len();
        let bucket = self
            .engine
            .manifest
            .batch_bucket(b)
            .ok_or_else(|| anyhow!("batch {b} exceeds largest bucket"))?;

        let mut tokens = vec![0i32; bucket];
        let mut pos = vec![0i32; bucket];
        let mut lens = vec![0i32; bucket];
        let mut slots = vec![PAD_SLOT; bucket];
        let mut max_len_after = 1usize;
        for (i, &si) in active.iter().enumerate() {
            let s = &wave[si];
            tokens[i] = s.next_input;
            pos[i] = s.len;
            lens[i] = s.len;
            slots[i] = s.cache_slot;
            max_len_after = max_len_after.max(s.len as usize + 1);
        }
        let seq_bucket = self
            .engine
            .manifest
            .seq_bucket(max_len_after)
            .ok_or_else(|| anyhow!("context {max_len_after} exceeds max seq bucket"))?;

        if step_trace_enabled() {
            let ids: Vec<u64> = active.iter().map(|&si| wave[si].request_id).collect();
            eprintln!(
                "[step-trace] reqs={ids:?} slots={slots:?} lens={lens:?} \
                 bucket={bucket} seq_bucket={seq_bucket}"
            );
        }

        let tokens_t = HostTensor::i32(vec![bucket], tokens);
        let pos_t = HostTensor::i32(vec![bucket], pos);

        let mut model_s = 0.0;
        let mut attn_wait_s = 0.0;

        // slice_first
        let t0 = Instant::now();
        let mut outs = self.engine.execute(
            "slice_first",
            bucket,
            None,
            &[&tokens_t, &pos_t],
            &first_weight_names(),
        )?;
        model_s += t0.elapsed().as_secs_f64();
        let (mut q, mut k, mut v, mut resid) = take4(&mut outs)?;

        for layer in 0..mc.layers {
            // ship q early, then k/v (the §4.2.2 ordering)
            self.send_q(layer, &slots, &q, &lens, seq_bucket)?;
            self.send_kv(layer, &k, &v)?;
            let t1 = Instant::now();
            let attn_out = self.recv_attn(layer, bucket)?;
            attn_wait_s += t1.elapsed().as_secs_f64();

            let t2 = Instant::now();
            if layer + 1 < mc.layers {
                let mut outs = self.engine.execute(
                    "slice_mid",
                    bucket,
                    None,
                    &[&attn_out, &resid, &pos_t],
                    &mid_weight_names(layer),
                )?;
                model_s += t2.elapsed().as_secs_f64();
                let (q2, k2, v2, r2) = take4(&mut outs)?;
                q = q2;
                k = k2;
                v = v2;
                resid = r2;
            } else {
                let outs = self.engine.execute(
                    "slice_last",
                    bucket,
                    None,
                    &[&attn_out, &resid],
                    &last_weight_names(mc.layers),
                )?;
                model_s += t2.elapsed().as_secs_f64();
                let next = outs
                    .into_iter()
                    .nth(1)
                    .ok_or_else(|| anyhow!("slice_last output arity"))?;
                let total = step_t0.elapsed().as_secs_f64();
                let net_bytes = self.step_net_bytes.get();
                let net_model_s = (self.opts.stack.fixed_overhead()
                    + net_bytes as f64 / (LINE_RATE_400G * self.opts.stack.bw_efficiency))
                    * self.opts.time_scale.min(1.0);
                let bd = StepBreakdown {
                    model_s,
                    attn_s: attn_wait_s,
                    network_s: net_model_s,
                    sched_s: (total - model_s - attn_wait_s - net_model_s).max(0.0),
                    total_s: total,
                };
                let mut next_tokens = next.as_i32()[..bucket].to_vec();
                next_tokens.truncate(b.max(1));
                return Ok((next_tokens, bd));
            }
        }
        unreachable!("loop returns at last layer");
    }

    /// Advance a wave by one decode step: pick active slots, run the step,
    /// apply teacher forcing for unconsumed prompt tokens, collect outputs.
    fn step_wave(&self, wave: &mut Vec<SlotState>) -> Result<Option<StepBreakdown>> {
        let active: Vec<usize> = (0..wave.len()).filter(|&i| !wave[i].done()).collect();
        if active.is_empty() {
            return Ok(None);
        }
        let (next, bd) = self.decode_step(wave, &active)?;
        for (row, &si) in active.iter().enumerate() {
            let s = &mut wave[si];
            s.len += 1;
            let produced = next[row];
            s.next_input = if let Some(tok) = s.pending_prompt.first().copied() {
                s.pending_prompt.remove(0);
                tok
            } else {
                if s.generated.len() < s.gen_target {
                    s.generated.push(produced);
                }
                produced
            };
        }
        Ok(Some(bd))
    }

    // ---- chunked prefill (paper §5) ---------------------------------------

    /// Prefill `prompt` for cache slot `slot` in chunks of the largest batch
    /// bucket, returning the first generated token. The KV lands on the
    /// attention workers layer-by-layer exactly as the paper's transition
    /// protocol streams it.
    pub fn prefill(&self, slot: u32, prompt: &[i32]) -> Result<i32> {
        let mc = self.config().clone();
        assert!(!prompt.is_empty());
        let chunk = *self
            .engine
            .manifest
            .batch_buckets
            .iter()
            .max()
            .ok_or_else(|| anyhow!("no batch buckets"))?;
        let mut cached = 0usize;
        let mut next_token = 0i32;
        while cached < prompt.len() {
            let valid = (prompt.len() - cached).min(chunk);
            let bucket = self
                .engine
                .manifest
                .batch_bucket(valid)
                .ok_or_else(|| anyhow!("chunk exceeds buckets"))?;
            let seq_bucket = self
                .engine
                .manifest
                .seq_bucket(cached + bucket)
                .ok_or_else(|| anyhow!("prompt exceeds context window"))?;

            let mut tokens = vec![0i32; bucket];
            let mut pos = vec![0i32; bucket];
            for i in 0..valid {
                tokens[i] = prompt[cached + i];
                pos[i] = (cached + i) as i32;
            }
            for (i, p) in pos.iter_mut().enumerate().skip(valid) {
                *p = (cached + i) as i32; // padding rows: harmless positions
            }
            let tokens_t = HostTensor::i32(vec![bucket], tokens);
            let pos_t = HostTensor::i32(vec![bucket], pos);

            let mut outs = self.engine.execute(
                "slice_first",
                bucket,
                None,
                &[&tokens_t, &pos_t],
                &first_weight_names(),
            )?;
            let (mut q, mut k, mut v, mut resid) = take4(&mut outs)?;

            for layer in 0..mc.layers {
                self.send_prefill(layer, slot, &q, &k, &v, cached as i32, valid, seq_bucket)?;
                let attn_out = self.recv_attn(layer, bucket)?;
                if layer + 1 < mc.layers {
                    let mut outs = self.engine.execute(
                        "slice_mid",
                        bucket,
                        None,
                        &[&attn_out, &resid, &pos_t],
                        &mid_weight_names(layer),
                    )?;
                    let (q2, k2, v2, r2) = take4(&mut outs)?;
                    q = q2;
                    k = k2;
                    v = v2;
                    resid = r2;
                } else {
                    let outs = self.engine.execute(
                        "slice_last",
                        bucket,
                        None,
                        &[&attn_out, &resid],
                        &last_weight_names(mc.layers),
                    )?;
                    let next = &outs[1];
                    next_token = next.as_i32()[valid - 1];
                }
            }
            cached += valid;
        }
        Ok(next_token)
    }

    /// Pool-wide wire-traffic accounting: per-message-class logical
    /// (`wire_bytes()` model) and measured serialized bytes, summed over
    /// every leader-side link endpoint since pipeline start. Serialized
    /// bytes are only non-zero on serializing transports (`tcp`).
    pub fn wire_stats(&self) -> crate::net::WireStats {
        let mut sum = self.retired_wire;
        for worker in &self.workers {
            sum.merge(&worker.link.stats());
        }
        sum
    }

    /// The transport this pipeline was started with.
    pub fn transport(&self) -> TransportKind {
        self.opts.transport
    }

    /// The attention backend the workers were started with.
    pub fn attn_backend(&self) -> AttnBackendKind {
        self.opts.attn_backend
    }

    /// The KV block storage dtype the workers' arenas run.
    pub fn kv_dtype(&self) -> KvDtype {
        self.opts.kv_dtype
    }

    #[allow(clippy::too_many_arguments)]
    fn send_prefill(
        &self,
        layer: usize,
        slot: u32,
        q: &HostTensor,
        k: &HostTensor,
        v: &HostTensor,
        cached: i32,
        valid: usize,
        seq_bucket: usize,
    ) -> Result<()> {
        let mc = self.config();
        let w = self.workers.len();
        let hs = mc.heads / w;
        let khs = mc.kv_heads / w;
        for (wi, worker) in self.workers.iter().enumerate() {
            let msg = WireMsg::PrefillChunk {
                layer,
                slot,
                q: slice_heads(q, wi * hs, hs),
                k: slice_heads(k, wi * khs, khs),
                v: slice_heads(v, wi * khs, khs),
                cached,
                valid,
                seq_bucket,
            };
            self.step_net_bytes.set(self.step_net_bytes.get() + msg.wire_bytes());
            worker.link.send(msg).map_err(|e| anyhow!(e))?;
        }
        Ok(())
    }

    /// Prefill-then-decode: run the prompt through the chunked prefill path,
    /// then greedy-decode `steps` tokens. Must produce exactly the same
    /// tokens as the teacher-forced `decode` path (asserted in tests).
    pub fn generate(&self, slot: u32, prompt: &[i32], steps: usize) -> Result<Vec<i32>> {
        let first = self.prefill(slot, prompt)?;
        let mut wave = vec![SlotState {
            request_id: slot as u64,
            cache_slot: slot,
            pending_prompt: Vec::new(),
            len: prompt.len() as i32,
            generated: vec![first],
            gen_target: steps,
            next_input: first,
            kv_reserved: 0,
        }];
        while wave[0].generated.len() < steps {
            let (next, _) = self.decode_step(&mut wave, &[0])?;
            let s = &mut wave[0];
            s.len += 1;
            s.generated.push(next[0]);
            s.next_input = next[0];
        }
        let mut out = wave.remove(0).generated;
        out.truncate(steps);
        Ok(out)
    }

    // ---- public decoding APIs --------------------------------------------

    /// Greedy-decode `steps` tokens for each prompt (single wave, batch =
    /// prompts.len(), must fit in the slot count). Returns generated ids.
    pub fn decode(&self, prompts: &[Vec<i32>], steps: usize) -> Result<Vec<Vec<i32>>> {
        if prompts.len() > self.opts.slots {
            bail!("batch {} exceeds slots {}", prompts.len(), self.opts.slots);
        }
        let mut wave: Vec<SlotState> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                assert!(!p.is_empty(), "empty prompt");
                SlotState {
                    request_id: i as u64,
                    cache_slot: i as u32,
                    pending_prompt: p[1..].to_vec(),
                    len: 0,
                    generated: Vec::new(),
                    gen_target: steps,
                    next_input: p[0],
                    kv_reserved: 0,
                }
            })
            .collect();
        while self.step_wave(&mut wave)?.is_some() {}
        Ok(wave.into_iter().map(|s| s.generated).collect())
    }

    /// Serve a request list with continuous batching across `waves`
    /// staggered waves. Requests use synthetic prompts of the declared
    /// lengths (the traces carry lengths only, like the paper's). Slot-based
    /// admission: a waiting request joins as soon as a slot in some wave
    /// frees up (iteration-granularity batching).
    pub fn serve(&self, requests: &[Request], waves: usize) -> Result<ServeMetrics> {
        let mc = self.config();
        assert!(waves >= 1, "need at least one wave");
        assert!(
            waves <= self.opts.max_waves,
            "waves {waves} exceed max_waves {} (slot pools)",
            self.opts.max_waves
        );
        let max_ctx = mc.max_seq - 1;
        for r in requests {
            if r.max_context() > max_ctx {
                bail!(
                    "request {} context {} exceeds tiny-model max {max_ctx}",
                    r.id,
                    r.max_context()
                );
            }
        }
        let mut waiting: std::collections::VecDeque<Request> =
            requests.iter().copied().collect();
        let mut waves_state: Vec<Vec<SlotState>> = (0..waves).map(|_| Vec::new()).collect();
        // physical cache slots are partitioned across waves and recycled via
        // a per-wave free list (stable for each request's lifetime)
        let mut free_slots: Vec<Vec<u32>> = (0..waves)
            .map(|w| {
                (0..self.opts.slots as u32)
                    .map(|s| (w * self.opts.slots) as u32 + s)
                    .rev()
                    .collect()
            })
            .collect();
        let mut metrics = ServeMetrics::new();
        let mut rng = crate::util::prng::Rng::new(0x1a31a);
        let workers_n = self.workers.len().max(1);
        // endpoint counters run from pipeline start; report only this
        // session's traffic (snapshot before the first control-plane poll)
        let wire_baseline = self.wire_stats();
        // KV admission-control state: latest pool snapshot (refreshed every
        // round) + running per-worker block reservation of live requests
        // (each request is reserved its full-context footprint on admission;
        // block counts are worker-invariant under head-level sharding)
        let mut kv_snap = self.kv_stats()?;
        let mut live_reserved: usize = 0;

        loop {
            // admission: fill free slots round-robin across waves; with a
            // KV budget, a request that would overflow the workers' arenas
            // is deferred until retirements free blocks (FIFO preserved)
            let mut any_live = waves_state.iter().any(|w| !w.is_empty());
            let mut admission_blocked = false;
            for (wi, ws) in waves_state.iter_mut().enumerate() {
                if admission_blocked {
                    break;
                }
                while let Some(&slot) = free_slots[wi].last() {
                    let Some(r) = waiting.front().copied() else { break };
                    let needed = kv_blocks_needed(&[r.max_context()], self.opts.kv_block_size);
                    if let Some(budget) = self.opts.kv_block_budget {
                        // worst-case per-worker residency if r joins: live
                        // reservations (requests grow to full context) or
                        // the measured snapshot, whichever is larger
                        let in_use = kv_snap.blocks_in_use.div_ceil(workers_n);
                        if any_live && live_reserved.max(in_use) + needed > budget {
                            metrics.record_deferred_admission();
                            admission_blocked = true;
                            break;
                        }
                        // with no live request to wait for, admission
                        // proceeds regardless (deferring could never free
                        // blocks) — the budget is a back-pressure valve,
                        // not a hard rejection
                    }
                    waiting.pop_front();
                    free_slots[wi].pop();
                    live_reserved += needed;
                    any_live = true;
                    let prompt: Vec<i32> = (0..r.prompt_tokens.max(1))
                        .map(|_| rng.range(1, mc.vocab as u64) as i32)
                        .collect();
                    if self.opts.use_prefill && prompt.len() > 1 {
                        // chunked prefill populates the KV cache; the first
                        // generated token comes out of the prefill pass
                        let first = self.prefill(slot, &prompt)?;
                        ws.push(SlotState {
                            request_id: r.id,
                            cache_slot: slot,
                            pending_prompt: Vec::new(),
                            len: prompt.len() as i32,
                            generated: vec![first],
                            gen_target: r.gen_tokens,
                            next_input: first,
                            kv_reserved: needed,
                        });
                    } else {
                        ws.push(SlotState {
                            request_id: r.id,
                            cache_slot: slot,
                            pending_prompt: prompt[1..].to_vec(),
                            len: 0,
                            generated: Vec::new(),
                            gen_target: r.gen_tokens,
                            next_input: prompt[0],
                            kv_reserved: needed,
                        });
                    }
                }
            }
            if waves_state.iter().all(|w| w.is_empty()) && waiting.is_empty() {
                break;
            }

            // one round: step every wave (worker threads overlap waves'
            // attention with the leader's slices of the other wave)
            let mut retired: Vec<u32> = Vec::new();
            for (wi, ws) in waves_state.iter_mut().enumerate() {
                let decoding = ws
                    .iter()
                    .filter(|s| s.pending_prompt.is_empty() && !s.done())
                    .count();
                if let Some(bd) = self.step_wave(ws)? {
                    // only decode-phase tokens count toward serving metrics
                    if decoding > 0 {
                        metrics.record_step(decoding, bd);
                    }
                }
                let before = ws.len();
                ws.retain(|s| {
                    if s.done() {
                        free_slots[wi].push(s.cache_slot); // recycle KV slot
                        retired.push(s.cache_slot);
                        live_reserved -= s.kv_reserved;
                        false
                    } else {
                        true
                    }
                });
                metrics.record_completion((before - ws.len()) as u64);
            }

            // per-round KV occupancy snapshot, taken BEFORE retiring the
            // round's completed requests so kv_peak_blocks reflects true
            // residency (a request that finishes in its first round must
            // still show up in the peak); the same snapshot feeds the next
            // round's admission check
            kv_snap = self.kv_stats()?;
            metrics.record_kv(kv_snap);

            // now free the finished requests' KV blocks on every worker —
            // arena residency tracks live context, not slot capacity
            for slot in retired {
                self.retire_slot(slot)?;
            }
        }
        // pool-wide wire accounting: measured serialized bytes next to the
        // logical wire_bytes() model, per message class (this session only)
        metrics.record_wire(&self.wire_stats().delta_since(&wire_baseline));
        Ok(metrics)
    }

    // ---- fault tolerance (paper §5) ---------------------------------------

    /// Simulate an attention-worker failure: its thread is terminated and
    /// all its KV state (the head shard of every live request) is lost.
    pub fn kill_attn_worker(&mut self, idx: usize) {
        let w = &mut self.workers[idx];
        let _ = w.link.send(WireMsg::Shutdown);
        if let Some(t) = w.thread.take() {
            let _ = t.join();
        }
    }

    /// Recover a failed attention worker: spawn a replacement with an empty
    /// cache, then rebuild the lost KV by re-running each live request's
    /// prompt + already-generated tokens (kept by the service front-end)
    /// through the chunked-prefill path. Prefill broadcasts to all workers;
    /// healthy shards are overwritten with byte-identical values, so the
    /// rebuild is idempotent.
    pub fn recover_attn_worker(
        &mut self,
        idx: usize,
        live: &[(u32, Vec<i32>)],
    ) -> Result<()> {
        // keep the failed link's traffic in the pool totals before the
        // handle (and its counters) is replaced
        self.retired_wire.merge(&self.workers[idx].link.stats());
        let geom = ModelGeom::of(self.config());
        self.workers[idx] = spawn_worker(&self.opts, geom, idx, true)?;
        for (slot, tokens) in live {
            assert!(!tokens.is_empty());
            // re-prefill the full known token history; the final next-token
            // output is discarded (decode continues from the caller's state)
            let _ = self.prefill(*slot, tokens)?;
        }
        Ok(())
    }

    pub fn shutdown(mut self) {
        for w in &self.workers {
            let _ = w.link.send(WireMsg::Shutdown);
        }
        for w in &mut self.workers {
            if let Some(t) = w.thread.take() {
                let _ = t.join();
            }
        }
    }
}

/// `LAMINA_STEP_TRACE=1` logs every decode step's request ids, cache slots
/// and context lengths (checked once, cached).
fn step_trace_enabled() -> bool {
    static ON: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *ON.get_or_init(|| std::env::var_os("LAMINA_STEP_TRACE").is_some())
}

/// Slice heads `[h0, h0+n)` out of `[B, H, hd]`. The full-range slice (the
/// single-worker steady state) is a zero-copy Arc view; a genuine shard
/// slice must interleave rows and is charged to [`copies`].
fn slice_heads(t: &HostTensor, h0: usize, n: usize) -> HostTensor {
    let shape = t.shape();
    assert_eq!(shape.len(), 3);
    let (b, h, hd) = (shape[0], shape[1], shape[2]);
    assert!(h0 + n <= h);
    if h0 == 0 && n == h {
        return t.clone();
    }
    let src = t.as_f32();
    let mut out = vec![0.0f32; b * n * hd];
    for bi in 0..b {
        let s = (bi * h + h0) * hd;
        let d = bi * n * hd;
        out[d..d + n * hd].copy_from_slice(&src[s..s + n * hd]);
    }
    copies::add(b * n * hd * 4);
    HostTensor::f32(vec![b, n, hd], out)
}

fn take4(outs: &mut Vec<HostTensor>) -> Result<(HostTensor, HostTensor, HostTensor, HostTensor)> {
    if outs.len() != 4 {
        bail!("expected 4 outputs, got {}", outs.len());
    }
    let r = outs.pop().unwrap();
    let v = outs.pop().unwrap();
    let k = outs.pop().unwrap();
    let q = outs.pop().unwrap();
    Ok((q, k, v, r))
}

fn first_weight_names() -> Vec<String> {
    ["embed", "layer0.attn_norm", "layer0.wq", "layer0.wk", "layer0.wv"]
        .iter()
        .map(|s| s.to_string())
        .collect()
}

fn mid_weight_names(layer: usize) -> Vec<String> {
    let i = layer;
    let j = layer + 1;
    vec![
        format!("layer{i}.wo"),
        format!("layer{i}.ffn_norm"),
        format!("layer{i}.w_gate"),
        format!("layer{i}.w_up"),
        format!("layer{i}.w_down"),
        format!("layer{j}.attn_norm"),
        format!("layer{j}.wq"),
        format!("layer{j}.wk"),
        format!("layer{j}.wv"),
    ]
}

fn last_weight_names(layers: usize) -> Vec<String> {
    let i = layers - 1;
    vec![
        format!("layer{i}.wo"),
        format!("layer{i}.ffn_norm"),
        format!("layer{i}.w_gate"),
        format!("layer{i}.w_up"),
        format!("layer{i}.w_down"),
        "final_norm".to_string(),
        "lm_head".to_string(),
    ]
}
