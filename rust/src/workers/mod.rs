//! Worker actors of the real (tiny-model) disaggregated pipeline: the
//! model-worker leader and the head-sharded attention workers, exchanging
//! tensors over the paced in-process network.

pub mod attn_worker;
pub mod leader;
pub mod messages;

pub use leader::{DisaggPipeline, PipelineOpts};
pub use messages::WireMsg;
