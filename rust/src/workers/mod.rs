//! Worker actors of the real (tiny-model) disaggregated pipeline.
//!
//! The **leader** ([`leader::DisaggPipeline`]) is the paper's
//! compute-optimised model worker: it executes the non-attention slices
//! through PJRT and drives the decode loop. The **attention workers**
//! ([`attn_worker`]) are the memory-optimised pool: each owns a head shard
//! (`KH/W` KV heads) of *every* request's KV cache and runs the attention
//! math for it. Tensors cross between them over a pluggable
//! [`crate::net::Transport`] — the paced in-process channel
//! (`netsim::transport`, `--transport inproc`) or real TCP loopback
//! sockets carrying serialized `net::codec` frames (`--transport tcp`) —
//! preserving the paper's §4.2.2 Q-early overlap over either wire. Both
//! worker loops are generic over the trait; the full decode +
//! chunked-prefill session is bit-identical across transports (asserted
//! by `tests/net_e2e.rs`).
//!
//! # Serving: a request-lifecycle engine (continuous batching)
//!
//! The leader's public surface is step-driven and request-shaped — the
//! engine owns slots, admission, and step composition; callers own
//! nothing but their request ids:
//!
//! ```text
//!   submit() ─▶ Queued ─admit─▶ Prefilling ─last chunk─▶ Decoding ─target─▶ Finished{Completed}
//!                 │               (teacher-forced requests skip Prefilling)        ▲
//!                 └──────────────────────── cancel() ───────────────▶ Finished{Cancelled}
//!
//!   step()  =  admit (policy + KV budget)  →  one prefill chunk │ one decode
//!              iteration over the running batch  →  retire finishes
//! ```
//!
//! Requests join and leave the running batch at **iteration** granularity
//! (Orca-style continuous batching). The scheduling control plane — the
//! waiting queue, the per-request state machine above, the dynamic slot
//! pool, and the pluggable admission policy (`--admission fifo|sjf`,
//! budget in KV blocks or bytes) — lives in [`crate::scheduler`] and is
//! property-tested without artifacts; this module executes its plans.
//!
//! **Who owns slots now:** the scheduler hands each admitted request a
//! physical cache slot from a free pool and recycles it at retirement.
//! The slot→wire mapping (`StepQ.slots`, `PrefillChunk.slot`,
//! `Retire.slot`) is unchanged — attention workers are oblivious to the
//! redesign. The paper's §4.3 staggered waves survive only as a driver
//! loop (`serve_waves`, `GroupMode::ByWave`) for comparison benches;
//! `serve` itself is a thin driver over submit/step/drain.
//!
//! # Memory: block-paged KV arenas
//!
//! Each worker keeps its shard in a [`crate::kvcache::PagedKvArena`] — per
//! layer, one contiguous `[total_blocks, KH_shard, block_size, hd]` K and V
//! buffer carved into fixed-size blocks, mapped per request slot by a
//! `BlockTable`, stored in the worker's `--kv-dtype` (f32, f16, or int8
//! with per-block scales — appends quantize in place; the wire stays f32).
//! Resident memory scales with **allocated blocks** (live context), not
//! `slots × max_waves × max_seq`: the arena grows on demand and the leader
//! frees a request's blocks with `WireMsg::Retire` the moment it
//! completes. `WireMsg::KvStatsReq` feeds occupancy + internal-waste
//! accounting — in blocks and dtype-aware **bytes** — into `ServeMetrics`
//! every serve round.
//!
//! Blocks are refcounted, so with `--prefix-cache` the stats carry two
//! views: **logical** (`blocks_in_use` — block-table entries summed over
//! slots, what capacity planning reserves against) and **physical**
//! (`physical_blocks_in_use` — distinct resident blocks, what the memory
//! actually holds). Logical ÷ physical is the prefix-sharing dedup
//! factor; with sharing off the two are equal. `WireMsg::MapBlocks` maps
//! a donor slot's prompt prefix into a new slot (refcount + copy-on-write
//! divergence), and `Retire` *releases* references rather than freeing —
//! a shared block survives until its last holder retires.
//!
//! # Compute: pluggable attention backends
//!
//! The attention math runs through a [`crate::kernels::AttnBackend`]
//! selected per worker by `--attn-backend`:
//!
//! * `native` — the block-table-native kernel (`kernels::paged_attn`)
//!   consumes the arena's block tables directly and reads KV **in place**
//!   with an online-softmax recurrence: no gather, no scratch K/V, zero
//!   per-step host copies — and with quantized storage it reads the
//!   compact f16/int8 lanes natively (dequantize-in-register), cutting
//!   per-step KV bytes read 2×/≈4×. Needs no PJRT artifacts on the
//!   worker; batch fan-out runs on a persistent per-worker thread pool.
//! * `engine` — the PJRT path: the arena assembles contiguous f32
//!   `[bucket, KH_s, seq_bucket, hd]` inputs with block-granular gathers
//!   that widen quantized storage on read (the staging copy, charged to
//!   `runtime::host::copies`) and executes the AOT Pallas artifacts.
//!
//! # Transport: zero-copy wire path
//!
//! `HostTensor` payloads are `Arc`-backed views, so on the steady-state
//! decode path the leader↔worker byte path performs **no host deep-copies**:
//! Q/K/V staging uses full-range head slices (views), `WireMsg` sends move
//! an `Arc`, and a single worker's attention output is returned without
//! reassembly. Only genuine shard interleaving (W > 1) and the engine
//! backend's staging gathers copy, and both report what they moved through
//! `runtime::host::copies` — with the native backend the whole decode step
//! charges **zero** bytes (see `cargo bench` → `BENCH_decode.json`).
//! Simulated-network accounting is unchanged: `wire_bytes()` still charges
//! the logical payload size to the modelled link.
//!
//! # Deployment: real multi-host clusters (`lamina-attn`)
//!
//! Attention workers need not share the leader's process. The standalone
//! `lamina-attn` binary runs [`attn_worker`] behind `--listen HOST:PORT`;
//! the leader dials out with `--workers addr1,addr2,…`
//! ([`crate::net::Addr`] — `HOST:PORT`, IPv6 in brackets) instead of
//! spawning shard threads. Everything downstream of the connect is the
//! in-process protocol unchanged: same handshake, same frames, same
//! failover, bit-identical output (asserted by `tests/net_cluster.rs`
//! against real subprocesses). One leader connection = one worker
//! *session*; a daemon outlives its sessions:
//!
//! ```text
//!   leader                                lamina-attn daemon
//!   ──────                                ──────────────────
//!   dial addr ── bounded retry ladder ──▶ accept ─┐
//!       (HealthPolicy backoff, typed            session: Hello ─▶
//!        dial failure after N tries)            ◀─ Welcome (geometry,
//!                                                   epoch, KV range)
//!   decode/prefill steps ◀───────────────▶ data plane (batched
//!       per-step frame burst in ONE          envelopes, one writev
//!       envelope per worker; replies         per step per worker)
//!       gathered via poll(2) readiness
//!       loop across all workers
//!   Shutdown / drop link ────────────────▶ session ends (EOF) ─┘
//!                                          back to accept: a respawn
//!   re-dial same addr ──────────────────▶  re-dials the SAME daemon
//!                                          for a fresh session
//! ```
//!
//! Because "respawn" for a dialed worker is just a re-dial, daemon
//! processes survive leader-side declare-dead verdicts (hang, sever) —
//! while a daemon that truly dies (SIGKILL) exhausts the dial ladder and
//! flows into the same degrade path as a thread worker. The wire-level
//! batching + multiplexing live in [`crate::net`] (`net::batch` envelope
//! codec, `net::mux` poll loop); inproc transports keep the plain
//! unbatched path, preserving cross-transport bit-identity.
//!
//! # Failure handling: detection → declare dead → preempt-replay-rebuild
//!
//! Every wire operation in the leader is typed
//! ([`crate::net::TransportError`]) — a peer that dies, hangs, or emits
//! garbage can never panic the leader. Failures extend the lifecycle
//! diagram above:
//!
//! ```text
//!   recv ──deadline──▶ retry (backoff ×N) ──▶ declare DEAD ──▶ recover:
//!    │                                          │    preempt every live request
//!    └─ Disconnected / Codec / WorkerError ─────┘    (promoted-token replay)
//!                                                    respawn the worker (fresh arena)
//!                                                    flush Retires + KvStats barrier
//!                                                    re-prefill prompt ⧺ generated
//!                                                    resume decoding — bit-identical
//! ```
//!
//! With elastic membership the pool width itself is a recovery variable.
//! Every worker — spawned, respawned, or adopted — joins through a
//! versioned `Hello`/`Welcome` handshake, and every width change is an
//! **epoch-fenced reshard** (re-plan contiguous KV-head ranges over the
//! members, re-`Welcome` all of them, then a `KvStats` barrier that
//! discards replies from any older epoch):
//!
//! ```text
//!                         ┌────────────────────────────────────────────┐
//!   declare DEAD ──┬─────▶│ respawn (default): same width, fresh arena │
//!                  │      └────────────────────────────────────────────┘
//!                  │      ┌────────────────────────────────────────────┐
//!                  └─────▶│ DEGRADE (--no-respawn): reshard W → W−1    │
//!                         │ survivors; below --min-workers → typed     │
//!                         │ MembershipRefused, zero leaked blocks      │
//!                         └────────────────────────────────────────────┘
//!   adopt_worker() ──────▶ handshake joiner ─ quiesce ─ reshard W → W+1
//!
//!   every arrow above = preempt-all → epoch += 1 → Welcome all →
//!                       fenced barrier → replay (bit-identical)
//! ```
//!
//! Detection policy and membership policy live in
//! [`crate::coordinator::failover`]
//! ([`crate::coordinator::failover::HealthPolicy`]: recv deadline, bounded
//! retries, exponential backoff;
//! [`crate::coordinator::failover::MembershipPolicy`]: respawn vs degrade,
//! floor), the recovery procedure in [`leader::DisaggPipeline`]
//! (`auto_recover`), and deterministic fault injection in
//! [`crate::net::fault`] (`--fault-plan`). The [`chaos`] harness drives
//! all of it end-to-end without artifacts: real scheduler, real attention
//! workers, faulted links, scripted kill/adopt schedules, and a
//! pseudo-model whose constant-K attention makes recovered — or degraded —
//! output bit-comparable to an unfailed golden run. Failure telemetry
//! lands in the metrics registry (`failover.worker_deaths`,
//! `failover.recovery_ns`, `failover.degrades`, `failover.adoptions`,
//! `failover.reshard_ns`, …) and on the `failover` span track of the
//! trace timeline.

pub mod attn_worker;
pub mod chaos;
pub mod leader;
pub mod messages;
pub mod smoke;

pub use attn_worker::{run_attn_worker, AttnWorkerCfg, ModelGeom, PAD_SLOT};
pub use chaos::{run_chaos, ChaosCfg, ChaosFailure, ChaosReport};
pub use leader::{DisaggPipeline, PipelineOpts};
pub use messages::WireMsg;
pub use smoke::{run_trace_smoke, SmokeReport};
