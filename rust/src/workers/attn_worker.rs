//! Attention worker: owns a head shard of every request's KV cache and
//! executes the L1 Pallas attention artifacts for it (paper §5: head-level
//! partitioning — worker `w` of `W` owns `KH/W` KV heads of *all* requests).
//!
//! The worker is a thread with its own PJRT [`Engine`] (its "device"): it
//! receives `StepQ`/`StepKv` messages over its [`Transport`] link (paced
//! in-process channel or real TCP socket — see `crate::net`), appends
//! K/V into its **block-paged arena** ([`PagedKvArena`]), runs the
//! attention kernel (full, or partial+combine in overlap mode) and ships
//! the output shard back. KV residency scales with allocated blocks — the
//! arena grows on demand and frees a request's blocks on [`WireMsg::Retire`]
//! — and the kernel's contiguous input is assembled with block-granular
//! `copy_from_slice` gathers. [`WireMsg::KvStatsReq`] exposes occupancy and
//! internal waste for `ServeMetrics`.

use crate::kvcache::{ArenaCfg, PagedKvArena};
use crate::net::Transport;
use crate::runtime::engine::Engine;
use crate::runtime::host::HostTensor;

use super::messages::WireMsg;

/// Sentinel slot id marking a padded batch row (re-exported from the arena,
/// which skips pad rows in appends and gathers).
pub use crate::kvcache::arena::PAD_SLOT;

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct AttnWorkerCfg {
    pub artifacts_dir: std::path::PathBuf,
    /// This worker's index within the shard group.
    pub shard: usize,
    /// Total attention workers (must divide kv_heads).
    pub n_shards: usize,
    /// Number of batch slots addressable by the wire protocol.
    pub slots: usize,
    /// Token slots per KV block in the paged arena.
    pub kv_block_size: usize,
}

/// Run the worker loop until `Shutdown` or link closure, over any
/// [`Transport`] (paced in-process channel or a real TCP socket — the
/// protocol is identical). Intended to be the body of a dedicated thread
/// (the Engine is created inside — PJRT handles are not `Send`).
pub fn run_attn_worker<T: Transport>(cfg: AttnWorkerCfg, link: T) {
    let engine = match Engine::load(&cfg.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = link.send(WireMsg::WorkerError { msg: format!("engine load: {e:#}") });
            return;
        }
    };
    if let Err(e) = worker_loop(&engine, &cfg, &link) {
        let _ = link.send(WireMsg::WorkerError { msg: e });
    }
}

fn worker_loop<T: Transport>(engine: &Engine, cfg: &AttnWorkerCfg, link: &T) -> Result<(), String> {
    // pre-compile this shard's attention entry points (lazy compiles would
    // otherwise spike the first decode steps' latency)
    let sfx = if cfg.n_shards == 1 { String::new() } else { format!("_w{}", cfg.n_shards) };
    for e in &engine.manifest.entrypoints {
        let mine = e.entry == format!("attention{sfx}")
            || e.entry == format!("attn_prev{sfx}")
            || e.entry == format!("attn_combine{sfx}")
            || e.entry == format!("prefill_attn{sfx}");
        if mine {
            engine
                .execute_warm(&e.entry, e.batch, e.seq)
                .map_err(|err| format!("warmup {}: {err:#}", e.entry))?;
        }
    }
    let mc = &engine.manifest.config;
    assert_eq!(mc.kv_heads % cfg.n_shards, 0, "shards must divide kv heads");
    let khs = mc.kv_heads / cfg.n_shards;
    let hd = mc.head_dim;

    // this shard's paged KV store: all layers, every request's head shard.
    // Starts at one block per slot and grows with live context.
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: mc.layers,
        kv_heads: khs,
        head_dim: hd,
        max_seq: mc.max_seq,
        slots: cfg.slots,
        block_size: cfg.kv_block_size,
        initial_blocks: cfg.slots.max(1),
    });

    // state carried from StepQ to StepKv
    struct Pending {
        layer: usize,
        slots: Vec<u32>,
        q: HostTensor,
        lens: Vec<i32>,
        seq_bucket: usize,
        overlap: bool,
        /// overlap mode: (a_prev, s_prev, m_prev) computed on q arrival
        partial: Option<(HostTensor, HostTensor, HostTensor)>,
    }
    let mut pending: Option<Pending> = None;

    let entry_sfx = if cfg.n_shards == 1 {
        String::new()
    } else {
        format!("_w{}", cfg.n_shards)
    };

    loop {
        let Some(msg) = link.recv_timeout(std::time::Duration::from_secs(60))? else {
            return Err("worker idle timeout".into());
        };
        match msg {
            WireMsg::Shutdown => return Ok(()),
            WireMsg::Retire { slot } => arena.retire(slot),
            WireMsg::KvStatsReq => {
                link.send(WireMsg::KvStats { stats: arena.stats() })?;
            }
            WireMsg::StepQ { layer, slots, q, lens, seq_bucket, overlap } => {
                let bucket = q.shape()[0];
                let mut p = Pending {
                    layer,
                    slots,
                    q,
                    lens,
                    seq_bucket,
                    overlap,
                    partial: None,
                };
                if overlap {
                    // partial attention over cached tokens, before k/v exist
                    let (kc, vc) = arena.gather(&p.slots, layer, bucket, seq_bucket);
                    let lens_t = HostTensor::i32(vec![bucket], p.lens.clone());
                    let out = engine
                        .execute_raw(
                            &format!("attn_prev{entry_sfx}"),
                            bucket,
                            Some(seq_bucket),
                            &[&p.q, &kc, &vc, &lens_t],
                        )
                        .map_err(|e| format!("attn_prev: {e:#}"))?;
                    let mut it = out.into_iter();
                    p.partial = Some((
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                    ));
                }
                pending = Some(p);
            }
            WireMsg::StepKv { layer, k, v } => {
                let p = pending.take().ok_or("StepKv without StepQ")?;
                if p.layer != layer {
                    return Err(format!("layer mismatch: q@{} kv@{}", p.layer, layer));
                }
                let bucket = p.q.shape()[0];
                // append k/v at position lens[b] for each active row
                arena.append_step(&p.slots, layer, &k, &v, &p.lens);
                let out = if p.overlap {
                    let (a, s, m) = p.partial.as_ref().unwrap();
                    engine
                        .execute_raw(
                            &format!("attn_combine{entry_sfx}"),
                            bucket,
                            None,
                            &[&p.q, &k, &v, a, s, m],
                        )
                        .map_err(|e| format!("attn_combine: {e:#}"))?
                        .remove(0)
                } else {
                    let (kc, vc) = arena.gather(&p.slots, layer, bucket, p.seq_bucket);
                    let lens1: Vec<i32> = p.lens.iter().map(|&l| l + 1).collect();
                    let lens_t = HostTensor::i32(vec![bucket], lens1);
                    engine
                        .execute_raw(
                            &format!("attention{entry_sfx}"),
                            bucket,
                            Some(p.seq_bucket),
                            &[&p.q, &kc, &vc, &lens_t],
                        )
                        .map_err(|e| format!("attention: {e:#}"))?
                        .remove(0)
                };
                link.send(WireMsg::AttnOut { layer, out })?;
            }
            WireMsg::PrefillChunk { layer, slot, q, k, v, cached, valid, seq_bucket } => {
                let t = q.shape()[0];
                // gather this slot's cached prefix; drop the leading batch
                // dim with a zero-copy reshape to the kernel's [KH_s, S, hd]
                let (kc_b, vc_b) = arena.gather(&[slot], layer, 1, seq_bucket);
                let kc = kc_b.reshape(vec![khs, seq_bucket, hd]);
                let vc = vc_b.reshape(vec![khs, seq_bucket, hd]);
                let lens_t = HostTensor::i32(vec![1], vec![cached]);
                let out = engine
                    .execute_raw(
                        &format!("prefill_attn{entry_sfx}"),
                        t,
                        Some(seq_bucket),
                        &[&q, &kc, &vc, &lens_t, &k, &v],
                    )
                    .map_err(|e| format!("prefill_attn: {e:#}"))?
                    .remove(0);
                // append the chunk's valid K/V rows at cached.. positions
                arena.append_chunk(slot, layer, &k, &v, cached as usize, valid);
                link.send(WireMsg::AttnOut { layer, out })?;
            }
            other => return Err(format!("unexpected message {other:?}")),
        }
    }
}
