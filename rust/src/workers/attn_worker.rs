//! Attention worker: owns a head shard of every request's KV cache and
//! turns `StepQ`/`StepKv`/`PrefillChunk` traffic into attention output
//! shards (paper §5: head-level partitioning — each worker owns a
//! contiguous KV-head range of *all* requests, assigned by the leader's
//! `Welcome` handshake reply; ranges differ by at most one head when the
//! pool width does not divide the head count).
//!
//! The worker is a thread that receives wire messages over its
//! [`Transport`] link (paced in-process channel or real TCP socket — see
//! `crate::net`), appends K/V into its **block-paged arena**
//! ([`PagedKvArena`]) and runs attention through a pluggable
//! [`AttnBackend`] (`--attn-backend`):
//!
//! * `engine` — the PJRT path: gathers contiguous K/V from the arena (a
//!   per-layer-per-step host copy) and executes the AOT Pallas artifacts.
//! * `native` — the block-table-native path (`crate::kernels::paged_attn`):
//!   reads the arena **in place** through its block views, so the decode
//!   hot loop performs **zero** per-step KV copies — and needs no
//!   artifacts on the worker at all (geometry comes from
//!   [`ModelGeom`]).
//!
//! Hot-loop hygiene: entry-point names are resolved once per worker (in
//! the engine backend) and the per-step `lens+1` vector comes from a
//! reused scratch buffer — nothing is `format!`ed or re-allocated per
//! message on the steady-state decode path.
//!
//! KV residency scales with allocated blocks — the arena grows on demand
//! and frees a request's blocks on [`WireMsg::Retire`] — and
//! [`WireMsg::KvStatsReq`] exposes occupancy and internal waste (in blocks
//! **and bytes**) for `ServeMetrics`.
//!
//! The arena's block storage dtype is a per-worker choice
//! (`--kv-dtype f32|f16|int8`, [`AttnWorkerCfg::kv_dtype`]): appends
//! quantize in place and the native backend reads the compact lanes
//! directly, halving/quartering both per-step KV bytes read and resident
//! bytes per cached token. The wire is unaffected — K/V arrive f32 and
//! outputs leave f32 either way.

use crate::kernels::{AttnBackend, AttnBackendKind, EngineBackend, NativeBackend, PartialState};
use crate::kvcache::{ArenaCfg, KvDtype, PagedKvArena};
use crate::net::{Transport, TransportError};
use crate::obs;
use crate::runtime::host::HostTensor;
use crate::runtime::manifest::Manifest;

use super::messages::WireMsg;

/// Sentinel slot id marking a padded batch row (re-exported from the arena,
/// which skips pad rows in appends and gathers).
pub use crate::kvcache::arena::PAD_SLOT;

/// Model geometry the worker sizes its arena with (re-exported from
/// `crate::kernels`).
pub use crate::kernels::ModelGeom;

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct AttnWorkerCfg {
    pub artifacts_dir: std::path::PathBuf,
    /// This worker's index within the shard group (diagnostic: sent in
    /// `Hello`; the authoritative KV-head range arrives in `Welcome`).
    pub shard: usize,
    /// Total attention workers at spawn time. The engine backend needs it
    /// to pick its per-width artifact; the native data plane takes its
    /// geometry from `Welcome` instead.
    pub n_shards: usize,
    /// Number of batch slots addressable by the wire protocol (the arena
    /// itself is sized by the `Welcome` reply).
    pub slots: usize,
    /// Token slots per KV block in the paged arena.
    pub kv_block_size: usize,
    /// Storage dtype of the paged arena's block buffers (`--kv-dtype`):
    /// f32 (bit-exact), f16 (2× fewer KV bytes), or int8 with per-block
    /// scales (≈4× fewer). Worker-local; the wire stays f32.
    pub kv_dtype: KvDtype,
    /// Which compute backend runs the attention math.
    pub backend: AttnBackendKind,
    /// Model geometry for the native backend. `None` falls back to the
    /// artifact manifest; the engine backend always uses its manifest.
    pub geom: Option<ModelGeom>,
    /// Accept the leader's `Welcome` as the authoritative geometry
    /// instead of cross-checking it against local knowledge. The
    /// standalone `lamina-attn` binary sets this: a remote worker has no
    /// artifacts or manifest of its own, the handshake *is* its config.
    /// In-process workers keep it `false` so a leader/worker geometry
    /// disagreement stays a loud protocol fault.
    pub trust_welcome: bool,
}

/// How a worker loop ended abnormally. The two classes get opposite
/// exits: a **link** fault means the peer is gone (or the stream is
/// unrecoverable), so nobody is listening — exit silently; a **protocol**
/// fault (malformed traffic, backend failure) is reported back to the
/// leader as a best-effort `WireMsg::WorkerError` before exiting, so the
/// leader can attribute the death instead of just seeing a hang.
#[derive(Debug)]
enum WorkerFault {
    Link(TransportError),
    Protocol(String),
}

impl From<TransportError> for WorkerFault {
    fn from(e: TransportError) -> WorkerFault {
        WorkerFault::Link(e)
    }
}

impl From<String> for WorkerFault {
    fn from(msg: String) -> WorkerFault {
        WorkerFault::Protocol(msg)
    }
}

/// Run the worker loop until `Shutdown` or link closure, over any
/// [`Transport`] (paced in-process channel or a real TCP socket — the
/// protocol is identical). Intended to be the body of a dedicated thread
/// (the engine backend's PJRT handles are not `Send`).
pub fn run_attn_worker<T: Transport>(cfg: AttnWorkerCfg, link: T) {
    // every span/instant this thread records lands on the worker's own
    // timeline track (leader is track 0)
    obs::set_thread_track(cfg.shard as u64 + 1);
    // `geom` is this worker's *local* knowledge of the model geometry,
    // used to cross-check the leader's `Welcome`. `None` (standalone
    // binary with `trust_welcome`) means the handshake is authoritative.
    let (mut backend, geom): (Box<dyn AttnBackend>, Option<ModelGeom>) = match cfg.backend {
        AttnBackendKind::Engine => match EngineBackend::new(&cfg.artifacts_dir, cfg.n_shards) {
            Ok(b) => {
                let geom = b.geom();
                (Box::new(b), Some(geom))
            }
            Err(e) => {
                let _ = link.send(WireMsg::WorkerError { msg: e });
                return;
            }
        },
        AttnBackendKind::Native => {
            let geom = match cfg.geom {
                Some(g) => Some(g),
                None if cfg.trust_welcome => None,
                None => match Manifest::load(&cfg.artifacts_dir) {
                    Ok(m) => Some(ModelGeom::of(&m.config)),
                    Err(e) => {
                        let _ = link.send(WireMsg::WorkerError {
                            msg: format!(
                                "native backend needs ModelGeom and the manifest fallback \
                                 failed: {e}"
                            ),
                        });
                        return;
                    }
                },
            };
            (Box::new(NativeBackend::new()), geom)
        }
    };
    if let Err(e) = backend.warmup() {
        let _ = link.send(WireMsg::WorkerError { msg: e });
        return;
    }
    match worker_loop(backend.as_mut(), geom, &cfg, &link) {
        Ok(()) => {}
        // peer is gone (or framing is lost): there is nobody to tell
        Err(WorkerFault::Link(_)) => {}
        Err(WorkerFault::Protocol(msg)) => {
            let _ = link.send(WireMsg::WorkerError { msg });
        }
    }
}

fn worker_loop<T: Transport>(
    backend: &mut dyn AttnBackend,
    geom: Option<ModelGeom>,
    cfg: &AttnWorkerCfg,
    link: &T,
) -> Result<(), WorkerFault> {
    // Membership handshake: `Hello` is the first frame on every link —
    // spawned, respawned, or adopted. The leader validates the codec
    // version and replies `Welcome` with this worker's negotiated KV-head
    // range and the membership epoch; the arena is built from that reply,
    // so the worker has no data plane until it is welcomed.
    link.send(WireMsg::Hello {
        codec_version: crate::net::codec::FORMAT_VERSION as u32,
        shard: cfg.shard as u32,
    })?;

    // this shard's paged KV store: all layers, every request's head-range
    // shard. (Re)built on every `Welcome` — a mid-session re-Welcome is a
    // reshard: drop all cached blocks, adopt the new range and epoch.
    let mut arena: Option<PagedKvArena> = None;
    // membership epoch of the last Welcome, echoed on every KvStats so the
    // leader's reshard barrier can fence out stale snapshots
    let mut epoch: u64 = 0;

    // state carried from StepQ to StepKv
    struct Pending {
        layer: usize,
        slots: Vec<u32>,
        q: HostTensor,
        lens: Vec<i32>,
        seq_bucket: usize,
        overlap: bool,
        /// overlap mode: (A, S, m) over the cached tokens, computed on q
        /// arrival (before this step's K/V exists)
        partial: Option<PartialState>,
    }
    let mut pending: Option<Pending> = None;
    // reused per-step scratch for the post-append lens (`lens[b] + 1`)
    let mut lens1: Vec<i32> = Vec::new();

    // a data-plane message on an un-welcomed link is a protocol fault
    fn member<'a>(arena: &'a mut Option<PagedKvArena>) -> Result<&'a mut PagedKvArena, WorkerFault> {
        arena
            .as_mut()
            .ok_or_else(|| WorkerFault::Protocol("data message before Welcome".into()))
    }

    loop {
        let Some(msg) = link.recv_timeout(std::time::Duration::from_secs(60))? else {
            return Err(WorkerFault::Protocol("worker idle timeout".into()));
        };
        match msg {
            WireMsg::Shutdown => return Ok(()),
            WireMsg::Welcome {
                epoch: e,
                kv_start,
                kv_count,
                slots,
                kv_block_size,
                layers,
                head_dim,
                max_seq,
            } => {
                let _sp = obs::span("worker", "welcome").arg("epoch", e as i64);
                let (start, count) = (kv_start as usize, kv_count as usize);
                if count == 0 {
                    return Err(WorkerFault::Protocol(format!(
                        "welcome kv range {start}+{count} is empty"
                    )));
                }
                // cross-check against local geometry when we have one; a
                // trust_welcome worker takes the leader's word instead
                if let Some(g) = geom {
                    if start + count > g.kv_heads {
                        return Err(WorkerFault::Protocol(format!(
                            "welcome kv range {start}+{count} invalid for {} kv heads",
                            g.kv_heads
                        )));
                    }
                    if layers as usize != g.layers || head_dim as usize != g.head_dim {
                        return Err(WorkerFault::Protocol(format!(
                            "welcome geometry mismatch: layers {layers} vs {}, head_dim \
                             {head_dim} vs {}",
                            g.layers, g.head_dim
                        )));
                    }
                }
                // a mid-session re-Welcome is a reshard: the previous
                // arena's blocks and any StepQ awaiting its KV belong to
                // the dead geometry — drop both, the leader replays
                pending = None;
                epoch = e;
                arena = Some(PagedKvArena::new(ArenaCfg {
                    layers: layers as usize,
                    kv_heads: count,
                    head_dim: head_dim as usize,
                    max_seq: max_seq as usize,
                    slots: slots as usize,
                    block_size: kv_block_size as usize,
                    initial_blocks: (slots as usize).max(1),
                    dtype: cfg.kv_dtype,
                }));
            }
            WireMsg::Retire { slot } => {
                let _sp = obs::span("worker", "retire").arg("slot", slot as i64);
                member(&mut arena)?.retire(slot);
            }
            WireMsg::MapBlocks { slot, src_slot, tokens } => {
                member(&mut arena)?.map_prefix(slot, src_slot, tokens);
            }
            WireMsg::KvStatsReq => {
                let stats = member(&mut arena)?.stats();
                link.send(WireMsg::KvStats { stats, epoch })?;
            }
            WireMsg::StepQ { layer, slots, q, lens, seq_bucket, overlap } => {
                let mut p = Pending {
                    layer,
                    slots,
                    q,
                    lens,
                    seq_bucket,
                    overlap,
                    partial: None,
                };
                if overlap {
                    // partial attention over cached tokens, before k/v exist
                    let _sp = obs::span("worker", "attn_prev").arg("layer", layer as i64);
                    p.partial = Some(backend.attn_prev(
                        member(&mut arena)?,
                        &p.slots,
                        layer,
                        &p.q,
                        &p.lens,
                        seq_bucket,
                    )?);
                } else {
                    member(&mut arena)?;
                }
                pending = Some(p);
            }
            WireMsg::StepKv { layer, k, v } => {
                let _sp = obs::span("worker", "decode-attn").arg("layer", layer as i64);
                let p = pending
                    .take()
                    .ok_or_else(|| WorkerFault::Protocol("StepKv without StepQ".into()))?;
                if p.layer != layer {
                    return Err(WorkerFault::Protocol(format!(
                        "layer mismatch: q@{} kv@{}",
                        p.layer, layer
                    )));
                }
                // append k/v at position lens[b] for each active row
                let a = member(&mut arena)?;
                a.append_step(&p.slots, layer, &k, &v, &p.lens);
                let out = if p.overlap {
                    let prev = p.partial.as_ref().expect("overlap StepQ stored partial");
                    backend.attn_combine(&p.q, &k, &v, prev)?
                } else {
                    lens1.clear();
                    lens1.extend(p.lens.iter().map(|&l| l + 1));
                    backend.attention(a, &p.slots, layer, &p.q, &lens1, p.seq_bucket)?
                };
                link.send(WireMsg::AttnOut { layer, out })?;
            }
            WireMsg::PrefillChunk { layer, slot, q, k, v, cached, valid, seq_bucket } => {
                let _sp = obs::span("worker", "prefill")
                    .arg("layer", layer as i64)
                    .arg("slot", slot as i64)
                    .arg("valid", valid as i64);
                // attention over cached prefix + causal chunk, computed
                // BEFORE the chunk's K/V lands in the arena
                let a = member(&mut arena)?;
                let out = backend.prefill(a, slot, layer, &q, &k, &v, cached, seq_bucket)?;
                // append the chunk's valid K/V rows at cached.. positions
                a.append_chunk(slot, layer, &k, &v, cached as usize, valid);
                link.send(WireMsg::AttnOut { layer, out })?;
            }
            other => return Err(WorkerFault::Protocol(format!("unexpected message {other:?}"))),
        }
    }
}
