//! Attention worker: owns a head shard of every request's KV cache and
//! executes the L1 Pallas attention artifacts for it (paper §5: head-level
//! partitioning — worker `w` of `W` owns `KH/W` KV heads of *all* requests).
//!
//! The worker is a thread with its own PJRT [`Engine`] (its "device"): it
//! receives `StepQ`/`StepKv` messages over the simulated network, appends
//! K/V into its cache shard, runs the attention kernel (full, or
//! partial+combine in overlap mode) and ships the output shard back.

use crate::netsim::transport::Port;
use crate::runtime::engine::Engine;
use crate::runtime::host::HostTensor;

use super::messages::WireMsg;

/// Sentinel slot id marking a padded batch row (no backing request).
pub const PAD_SLOT: u32 = u32::MAX;

/// Per-slot KV cache shard: dense `[KH_shard, max_seq, hd]` per layer.
struct SlotCache {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Worker configuration.
#[derive(Debug, Clone)]
pub struct AttnWorkerCfg {
    pub artifacts_dir: std::path::PathBuf,
    /// This worker's index within the shard group.
    pub shard: usize,
    /// Total attention workers (must divide kv_heads).
    pub n_shards: usize,
    /// Number of batch slots to preallocate cache for.
    pub slots: usize,
}

/// Run the worker loop until `Shutdown` or link closure. Intended to be the
/// body of a dedicated thread (the Engine is created inside — PJRT handles
/// are not `Send`).
pub fn run_attn_worker(cfg: AttnWorkerCfg, port: Port<WireMsg>) {
    let engine = match Engine::load(&cfg.artifacts_dir) {
        Ok(e) => e,
        Err(e) => {
            let _ = port.send(WireMsg::WorkerError { msg: format!("engine load: {e:#}") }, 0);
            return;
        }
    };
    if let Err(e) = worker_loop(&engine, &cfg, &port) {
        let _ = port.send(WireMsg::WorkerError { msg: e }, 0);
    }
}

fn worker_loop(engine: &Engine, cfg: &AttnWorkerCfg, port: &Port<WireMsg>) -> Result<(), String> {
    // pre-compile this shard's attention entry points (lazy compiles would
    // otherwise spike the first decode steps' latency)
    let sfx = if cfg.n_shards == 1 { String::new() } else { format!("_w{}", cfg.n_shards) };
    for e in engine.manifest.entrypoints.clone() {
        let mine = e.entry == format!("attention{sfx}")
            || e.entry == format!("attn_prev{sfx}")
            || e.entry == format!("attn_combine{sfx}")
            || e.entry == format!("prefill_attn{sfx}");
        if mine {
            engine
                .execute_warm(&e.entry, e.batch, e.seq)
                .map_err(|err| format!("warmup {}: {err:#}", e.entry))?;
        }
    }
    let mc = &engine.manifest.config;
    assert_eq!(mc.kv_heads % cfg.n_shards, 0, "shards must divide kv heads");
    let khs = mc.kv_heads / cfg.n_shards;
    let hs = mc.heads / cfg.n_shards;
    let hd = mc.head_dim;
    let max_seq = mc.max_seq;
    let layer_stride = khs * max_seq * hd;

    // caches[slot] holds all layers contiguously: [layers, KH_s, max_seq, hd]
    let mut caches: Vec<SlotCache> = (0..cfg.slots)
        .map(|_| SlotCache {
            k: vec![0.0; mc.layers * layer_stride],
            v: vec![0.0; mc.layers * layer_stride],
        })
        .collect();

    // state carried from StepQ to StepKv
    struct Pending {
        layer: usize,
        slots: Vec<u32>,
        q: HostTensor,
        lens: Vec<i32>,
        seq_bucket: usize,
        overlap: bool,
        /// overlap mode: (a_prev, s_prev, m_prev) computed on q arrival
        partial: Option<(HostTensor, HostTensor, HostTensor)>,
    }
    let mut pending: Option<Pending> = None;

    let entry_sfx = if cfg.n_shards == 1 {
        String::new()
    } else {
        format!("_w{}", cfg.n_shards)
    };

    loop {
        let Some((msg, _bytes)) = port
            .recv_timeout(std::time::Duration::from_secs(60))
            .map_err(|e| e.to_string())?
        else {
            return Err("worker idle timeout".into());
        };
        match msg {
            WireMsg::Shutdown => return Ok(()),
            WireMsg::StepQ { layer, slots, q, lens, seq_bucket, overlap } => {
                let bucket = q.shape()[0];
                let mut p = Pending {
                    layer,
                    slots,
                    q,
                    lens,
                    seq_bucket,
                    overlap,
                    partial: None,
                };
                if overlap {
                    // partial attention over cached tokens, before k/v exist
                    let (kc, vc) = gather_cache(
                        &caches, &p.slots, layer, khs, max_seq, hd, bucket, seq_bucket,
                        layer_stride,
                    );
                    let lens_t = HostTensor::i32(vec![bucket], p.lens.clone());
                    let out = engine
                        .execute_raw(
                            &format!("attn_prev{entry_sfx}"),
                            bucket,
                            Some(seq_bucket),
                            &[&p.q, &kc, &vc, &lens_t],
                        )
                        .map_err(|e| format!("attn_prev: {e:#}"))?;
                    let mut it = out.into_iter();
                    p.partial = Some((
                        it.next().unwrap(),
                        it.next().unwrap(),
                        it.next().unwrap(),
                    ));
                }
                pending = Some(p);
            }
            WireMsg::StepKv { layer, k, v } => {
                let p = pending.take().ok_or("StepKv without StepQ")?;
                if p.layer != layer {
                    return Err(format!("layer mismatch: q@{} kv@{}", p.layer, layer));
                }
                let bucket = p.q.shape()[0];
                // append k/v at position lens[b] for each active row
                append_kv(
                    &mut caches, &p.slots, layer, &k, &v, &p.lens, khs, max_seq, hd,
                    layer_stride,
                );
                let out = if p.overlap {
                    let (a, s, m) = p.partial.as_ref().unwrap();
                    engine
                        .execute_raw(
                            &format!("attn_combine{entry_sfx}"),
                            bucket,
                            None,
                            &[&p.q, &k, &v, a, s, m],
                        )
                        .map_err(|e| format!("attn_combine: {e:#}"))?
                        .remove(0)
                } else {
                    let (kc, vc) = gather_cache(
                        &caches, &p.slots, layer, khs, max_seq, hd, bucket, p.seq_bucket,
                        layer_stride,
                    );
                    let lens1: Vec<i32> = p.lens.iter().map(|&l| l + 1).collect();
                    let lens_t = HostTensor::i32(vec![bucket], lens1);
                    engine
                        .execute_raw(
                            &format!("attention{entry_sfx}"),
                            bucket,
                            Some(p.seq_bucket),
                            &[&p.q, &kc, &vc, &lens_t],
                        )
                        .map_err(|e| format!("attention: {e:#}"))?
                        .remove(0)
                };
                let bytes = out.byte_size();
                port.send(WireMsg::AttnOut { layer, out }, bytes)
                    .map_err(|e| e.to_string())?;
            }
            WireMsg::PrefillChunk { layer, slot, q, k, v, cached, valid, seq_bucket } => {
                let t = q.shape()[0];
                // gather this slot's cache shard as [KH_s, S, hd]
                let (kc_b, vc_b) = gather_cache(
                    &caches, &[slot], layer, khs, max_seq, hd, 1, seq_bucket,
                    layer_stride,
                );
                let kc = HostTensor::f32(
                    vec![khs, seq_bucket, hd],
                    kc_b.as_f32().to_vec(),
                );
                let vc = HostTensor::f32(
                    vec![khs, seq_bucket, hd],
                    vc_b.as_f32().to_vec(),
                );
                let lens_t = HostTensor::i32(vec![1], vec![cached]);
                let out = engine
                    .execute_raw(
                        &format!("prefill_attn{entry_sfx}"),
                        t,
                        Some(seq_bucket),
                        &[&q, &kc, &vc, &lens_t, &k, &v],
                    )
                    .map_err(|e| format!("prefill_attn: {e:#}"))?
                    .remove(0);
                // append the chunk's valid K/V rows at cached.. positions
                append_chunk_kv(
                    &mut caches[slot as usize], layer, &k, &v, cached as usize,
                    valid, khs, max_seq, hd, layer_stride,
                );
                let bytes = out.byte_size();
                port.send(WireMsg::AttnOut { layer, out }, bytes)
                    .map_err(|e| e.to_string())?;
            }
            other => return Err(format!("unexpected message {other:?}")),
        }
        let _ = hs; // (shard width is implied by artifact shapes)
    }
}

/// Scatter a prefill chunk's K/V `[T, KH_s, hd]` rows `0..valid` into the
/// slot cache at positions `cached..cached+valid`.
#[allow(clippy::too_many_arguments)]
fn append_chunk_kv(
    cache: &mut SlotCache,
    layer: usize,
    k: &HostTensor,
    v: &HostTensor,
    cached: usize,
    valid: usize,
    khs: usize,
    max_seq: usize,
    hd: usize,
    layer_stride: usize,
) {
    let kd = k.as_f32();
    let vd = v.as_f32();
    assert!(cached + valid <= max_seq, "prefill KV overflow");
    for i in 0..valid {
        for h in 0..khs {
            let dst = layer * layer_stride + h * max_seq * hd + (cached + i) * hd;
            let src = (i * khs + h) * hd;
            cache.k[dst..dst + hd].copy_from_slice(&kd[src..src + hd]);
            cache.v[dst..dst + hd].copy_from_slice(&vd[src..src + hd]);
        }
    }
}

/// Copy the first `seq_bucket` cached tokens of each row's shard into
/// contiguous `[bucket, KH_s, seq_bucket, hd]` tensors for the kernel call.
#[allow(clippy::too_many_arguments)]
fn gather_cache(
    caches: &[SlotCache],
    slots: &[u32],
    layer: usize,
    khs: usize,
    max_seq: usize,
    hd: usize,
    bucket: usize,
    seq_bucket: usize,
    layer_stride: usize,
) -> (HostTensor, HostTensor) {
    let row = khs * seq_bucket * hd;
    let mut k = vec![0.0f32; bucket * row];
    let mut v = vec![0.0f32; bucket * row];
    for (b, &slot) in slots.iter().enumerate() {
        if slot == PAD_SLOT {
            continue; // padded row: leave zeros, masked out by lens = 0
        }
        let cache = &caches[slot as usize];
        let base = layer * layer_stride;
        for h in 0..khs {
            let src = base + h * max_seq * hd;
            let dst = b * row + h * seq_bucket * hd;
            let n = seq_bucket * hd;
            k[dst..dst + n].copy_from_slice(&cache.k[src..src + n]);
            v[dst..dst + n].copy_from_slice(&cache.v[src..src + n]);
        }
    }
    let shape = vec![bucket, khs, seq_bucket, hd];
    (HostTensor::f32(shape.clone(), k), HostTensor::f32(shape, v))
}

/// Scatter the new token's k/v `[bucket, KH_s, hd]` into each row's cache at
/// position `lens[b]`.
#[allow(clippy::too_many_arguments)]
fn append_kv(
    caches: &mut [SlotCache],
    slots: &[u32],
    layer: usize,
    k: &HostTensor,
    v: &HostTensor,
    lens: &[i32],
    khs: usize,
    max_seq: usize,
    hd: usize,
    layer_stride: usize,
) {
    let kd = k.as_f32();
    let vd = v.as_f32();
    for (b, &slot) in slots.iter().enumerate() {
        if slot == PAD_SLOT {
            continue;
        }
        let pos = lens[b] as usize;
        assert!(pos < max_seq, "KV overflow: pos {pos} ≥ {max_seq}");
        let cache = &mut caches[slot as usize];
        for h in 0..khs {
            let dst = layer * layer_stride + h * max_seq * hd + pos * hd;
            let src = (b * khs + h) * hd;
            cache.k[dst..dst + hd].copy_from_slice(&kd[src..src + hd]);
            cache.v[dst..dst + hd].copy_from_slice(&vd[src..src + hd]);
        }
    }
}
