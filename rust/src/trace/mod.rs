//! Workload traces (paper §6, Table 4).
//!
//! The paper evaluates on four production traces (Azure-Conv, Azure-Code,
//! Kimi-Conv, Kimi-TA) that publish only sequence-length statistics; this
//! module synthesises traces matching those statistics (lognormal lengths
//! fitted to the published means — the paper itself replays dummy tokens of
//! the recorded lengths) and provides fixed-length microbench workloads for
//! Figs. 12 & 14.

use crate::util::prng::{lognormal_from_mean_cv, Rng};

/// One inference request (decode-phase view: the prompt is already
/// prefilled; `prompt_tokens` sizes the initial KV, `gen_tokens` is the
/// decode work).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
}

impl Request {
    /// Max context this request reaches.
    pub fn max_context(&self) -> usize {
        self.prompt_tokens + self.gen_tokens
    }
}

/// Table-4 trace statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceSpec {
    pub name: &'static str,
    pub requests: usize,
    pub mean_prompt: f64,
    pub mean_gen: f64,
    /// Coefficient of variation for the synthetic lognormals. Production
    /// LLM length distributions are heavy-tailed; 1.0 is a standard fit.
    pub cv: f64,
}

pub const AZURE_CONV: TraceSpec = TraceSpec {
    name: "Azure-Conv",
    requests: 19366,
    mean_prompt: 1154.7,
    mean_gen: 211.1,
    cv: 1.0,
};

pub const AZURE_CODE: TraceSpec = TraceSpec {
    name: "Azure-Code",
    requests: 8819,
    mean_prompt: 2047.8,
    mean_gen: 27.9,
    cv: 1.0,
};

pub const KIMI_CONV: TraceSpec = TraceSpec {
    name: "Kimi-Conv",
    requests: 12031,
    mean_prompt: 12035.1,
    mean_gen: 342.6,
    cv: 1.0,
};

pub const KIMI_TA: TraceSpec = TraceSpec {
    name: "Kimi-TA",
    requests: 23608,
    mean_prompt: 8560.0,
    mean_gen: 182.1,
    cv: 1.0,
};

pub const ALL_TRACES: &[&TraceSpec] = &[&AZURE_CONV, &AZURE_CODE, &KIMI_CONV, &KIMI_TA];

pub fn trace_by_name(name: &str) -> Option<&'static TraceSpec> {
    ALL_TRACES
        .iter()
        .find(|t| t.name.eq_ignore_ascii_case(name))
        .copied()
}

/// Synthesize `n` requests matching `spec`'s statistics (n defaults to the
/// trace's request count; pass a smaller n for fast simulations — the
/// distribution is what matters).
pub fn synthesize(spec: &TraceSpec, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed ^ hash_name(spec.name));
    let (mu_p, sg_p) = lognormal_from_mean_cv(spec.mean_prompt, spec.cv);
    let (mu_g, sg_g) = lognormal_from_mean_cv(spec.mean_gen, spec.cv);
    (0..n)
        .map(|i| Request {
            id: i as u64,
            prompt_tokens: (rng.lognormal(mu_p, sg_p).round() as usize).max(1),
            gen_tokens: (rng.lognormal(mu_g, sg_g).round() as usize).max(1),
        })
        .collect()
}

fn hash_name(name: &str) -> u64 {
    name.bytes().fold(0xcbf2_9ce4_8422_2325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x1000_0000_01b3)
    })
}

/// Synthesize the serve driver's dummy prompt tokens for `reqs`: one
/// request → `prompt_tokens` random ids in `[1, vocab)`. The traces carry
/// lengths only (like the paper's), so token *content* is synthetic — but
/// it is drawn from one shared stream in request order, which makes a
/// request's prompt a function of its position in the trace, **not** of
/// admission order. Under the continuous-batching engine that invariance
/// is what lets FIFO sessions reproduce the old wave-mode serve
/// bit-for-bit, and SJF sessions stay comparable per request.
pub fn synth_prompts(reqs: &[Request], vocab: usize, seed: u64) -> Vec<Vec<i32>> {
    let mut rng = Rng::new(seed);
    reqs.iter()
        .map(|r| {
            (0..r.prompt_tokens.max(1))
                .map(|_| rng.range(1, vocab as u64) as i32)
                .collect()
        })
        .collect()
}

/// Fixed-length workload for the microbench figures (12 & 14).
pub fn fixed_length(n: usize, context: usize, gen: usize) -> Vec<Request> {
    (0..n)
        .map(|i| Request { id: i as u64, prompt_tokens: context, gen_tokens: gen })
        .collect()
}

/// Empirical summary of a request list (for Table-4 verification).
#[derive(Debug, Clone, Copy)]
pub struct TraceSummary {
    pub requests: usize,
    pub mean_prompt: f64,
    pub mean_gen: f64,
    pub max_context: usize,
}

pub fn summarize(reqs: &[Request]) -> TraceSummary {
    let n = reqs.len().max(1) as f64;
    TraceSummary {
        requests: reqs.len(),
        mean_prompt: reqs.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / n,
        mean_gen: reqs.iter().map(|r| r.gen_tokens as f64).sum::<f64>() / n,
        max_context: reqs.iter().map(|r| r.max_context()).max().unwrap_or(0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_matches_table4_means() {
        for spec in ALL_TRACES {
            let reqs = synthesize(spec, 20_000, 42);
            let s = summarize(&reqs);
            let perr = (s.mean_prompt - spec.mean_prompt).abs() / spec.mean_prompt;
            let gerr = (s.mean_gen - spec.mean_gen).abs() / spec.mean_gen;
            assert!(perr < 0.05, "{}: prompt mean off {perr}", spec.name);
            assert!(gerr < 0.05, "{}: gen mean off {gerr}", spec.name);
        }
    }

    #[test]
    fn deterministic_per_seed_distinct_per_trace() {
        let a = synthesize(&AZURE_CONV, 100, 1);
        let b = synthesize(&AZURE_CONV, 100, 1);
        assert_eq!(a, b);
        let c = synthesize(&AZURE_CODE, 100, 1);
        assert_ne!(a[0], c[0]);
    }

    #[test]
    fn lengths_positive_and_heavy_tailed() {
        let reqs = synthesize(&KIMI_CONV, 10_000, 7);
        assert!(reqs.iter().all(|r| r.prompt_tokens >= 1 && r.gen_tokens >= 1));
        let s = summarize(&reqs);
        // heavy tail: max ≫ mean
        assert!(s.max_context as f64 > 4.0 * (s.mean_prompt + s.mean_gen));
    }

    #[test]
    fn fixed_length_uniform() {
        let reqs = fixed_length(8, 4096, 64);
        assert!(reqs.iter().all(|r| r.prompt_tokens == 4096 && r.gen_tokens == 64));
        assert_eq!(reqs.len(), 8);
    }

    #[test]
    fn synth_prompts_deterministic_per_position() {
        let reqs = vec![
            Request { id: 0, prompt_tokens: 5, gen_tokens: 2 },
            Request { id: 1, prompt_tokens: 3, gen_tokens: 2 },
        ];
        let a = synth_prompts(&reqs, 512, 7);
        let b = synth_prompts(&reqs, 512, 7);
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 5);
        assert_eq!(a[1].len(), 3);
        assert!(a.iter().flatten().all(|&t| (1..512).contains(&t)));
        // zero-length prompts are clamped to one token (as serve always did)
        let z = synth_prompts(&[Request { id: 0, prompt_tokens: 0, gen_tokens: 1 }], 16, 1);
        assert_eq!(z[0].len(), 1);
    }

    #[test]
    fn lookup() {
        assert_eq!(trace_by_name("kimi-ta").unwrap().requests, 23608);
        assert!(trace_by_name("nope").is_none());
    }
}
