//! Serving metrics, published through the **obs registry**.
//!
//! [`ServeMetrics`] is the per-session aggregator — token throughput,
//! time-between-tokens (TBT), batch-size tracking, the per-component
//! latency breakdown of Fig. 12, paged KV-cache accounting, wire
//! accounting (logical `wire_bytes()` model vs measured serialized frame
//! bytes), and per-request serving quality: queueing delay (submit →
//! admission), TTFT (submit → first generated token), inter-token latency
//! — each now with p50/p95/p99 (exact [`Percentiles`], not just means).
//!
//! The [`crate::obs::registry`] is the **single source of truth** for
//! cross-cutting counters and gauges: every `record_*` call here also
//! streams into registry metrics (`serve.tbt_ns`, `serve.ttft_ns`,
//! `serve.queue_ns` histograms; `serve.tokens`, `serve.requests`,
//! `serve.rejected`, `serve.preemptions` counters; `kv.*` occupancy
//! gauges), alongside the re-homed `runtime::host` byte counters
//! (`host.copied_bytes`, `kv.read_bytes`). A registry snapshot therefore
//! reflects the live session at any instant — `--metrics-dump` and ROADMAP
//! item 5's `/metrics` endpoint read it without touching this struct —
//! while `ServeMetrics` itself keeps the take-and-reset session-report
//! semantics the leader's `drain()` relies on. [`ServeMetrics::publish_registry`]
//! refreshes the end-of-session gauge view at drain time.

use std::sync::OnceLock;

use crate::net::WireStats;
use crate::obs::{self, Counter, Gauge, Histogram};
use crate::util::stats::{Percentiles, Welford};

/// Process-wide registry handles, resolved once and cached (the hot-path
/// cost of a `record_*` publication is the atomic op, not a map lookup).
mod reg {
    use super::*;

    macro_rules! cell {
        ($fn_name:ident, $ty:ident, $method:ident, $name:expr) => {
            pub(super) fn $fn_name() -> &'static $ty {
                static C: OnceLock<$ty> = OnceLock::new();
                C.get_or_init(|| obs::registry().$method($name))
            }
        };
    }

    cell!(tbt_ns, Histogram, histogram, "serve.tbt_ns");
    cell!(ttft_ns, Histogram, histogram, "serve.ttft_ns");
    cell!(queue_ns, Histogram, histogram, "serve.queue_ns");
    cell!(tokens, Counter, counter, "serve.tokens");
    cell!(requests, Counter, counter, "serve.requests");
    cell!(rejected, Counter, counter, "serve.rejected");
    cell!(preemptions, Counter, counter, "serve.preemptions");
    cell!(kv_blocks, Gauge, gauge, "kv.blocks_in_use");
    cell!(kv_bytes, Gauge, gauge, "kv.bytes_in_use");
    cell!(kv_physical_bytes, Gauge, gauge, "kv.physical_bytes_in_use");
    cell!(kv_peak_blocks, Gauge, gauge, "kv.peak_blocks");
    cell!(kv_peak_bytes, Gauge, gauge, "kv.peak_bytes");
    cell!(worker_deaths, Counter, counter, "failover.worker_deaths");
    cell!(recoveries, Counter, counter, "failover.recoveries");
    cell!(retries, Counter, counter, "failover.retries");
    cell!(tokens_replayed, Counter, counter, "failover.tokens_replayed");
    cell!(detection_ns, Histogram, histogram, "failover.detection_ns");
    cell!(recovery_ns, Histogram, histogram, "failover.recovery_ns");
    cell!(degrades, Counter, counter, "failover.degrades");
    cell!(adoptions, Counter, counter, "failover.adoptions");
    cell!(reshard_ns, Histogram, histogram, "failover.reshard_ns");
}

/// Registry-only publication from the leader's wire path: one receive
/// deadline expired and the health ladder granted a retry. No session
/// aggregate — the wire helpers run below the `ServeMetrics` layer.
pub fn note_failover_retry() {
    reg::retries().inc();
}

/// Registry-only publication: a worker was declared dead after
/// `detection_s` seconds of deadline/retry ladder (or immediately on a
/// fatal link error).
pub fn note_worker_death(detection_s: f64) {
    reg::worker_deaths().inc();
    reg::detection_ns().record_secs(detection_s);
}

/// Registry-only publication: the pool resharded to fewer workers after
/// an unreplaceable death (`--no-respawn` or respawn failure), taking
/// `reshard_s` seconds to re-plan geometry, re-welcome survivors and fence
/// the barrier.
pub fn note_degrade(reshard_s: f64) {
    reg::degrades().inc();
    reg::reshard_ns().record_secs(reshard_s);
}

/// Registry-only publication: the pool adopted a new worker and resharded
/// back up, taking `reshard_s` seconds.
pub fn note_adoption(reshard_s: f64) {
    reg::adoptions().inc();
    reg::reshard_ns().record_secs(reshard_s);
}

/// Snapshot of paged KV-cache occupancy, summed across attention workers.
///
/// `internal_waste_tokens` is the PagedAttention-style internal
/// fragmentation: token slots allocated in partially-filled tail blocks.
/// External fragmentation is impossible by construction (fixed-size
/// blocks).
///
/// Occupancy is reported in blocks **and bytes**: with quantized block
/// storage (`--kv-dtype f16|int8`) a block is 2×/≈4× smaller, so the
/// byte view is what shows the capacity gain on a fixed arena budget
/// (block counts alone cannot). `bytes_in_use`/`total_bytes` are
/// dtype-aware (int8 scale overhead included) and sum across workers
/// like the block counts.
///
/// With refcounted prefix sharing the **logical** view (`blocks_in_use`:
/// blocks mapped by request tables, a shared block counted once per
/// mapper) and the **physical** view (`physical_blocks_in_use`: distinct
/// resident blocks) diverge; logical ÷ physical is the dedup factor the
/// prefix cache achieves. Without sharing the two are equal.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvCacheStats {
    /// Logical blocks mapped by live request tables (shared blocks counted
    /// once per mapping table).
    pub blocks_in_use: usize,
    pub total_blocks: usize,
    pub block_size: usize,
    pub internal_waste_tokens: usize,
    /// Logical resident bytes (all layers, K+V, incl. int8 scales).
    pub bytes_in_use: usize,
    /// Resident bytes of the whole arena (allocated capacity).
    pub total_bytes: usize,
    /// Distinct physical blocks holding live KV (≤ `blocks_in_use`).
    pub physical_blocks_in_use: usize,
    /// Distinct physical resident bytes (≤ `bytes_in_use`).
    pub physical_bytes_in_use: usize,
}

impl KvCacheStats {
    /// Fraction of resident blocks holding live KV.
    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            0.0
        } else {
            self.blocks_in_use as f64 / self.total_blocks as f64
        }
    }

    /// Sum per-worker snapshots into a pool-wide view.
    pub fn merge(mut self, other: &KvCacheStats) -> KvCacheStats {
        self.blocks_in_use += other.blocks_in_use;
        self.total_blocks += other.total_blocks;
        self.internal_waste_tokens += other.internal_waste_tokens;
        self.block_size = self.block_size.max(other.block_size);
        self.bytes_in_use += other.bytes_in_use;
        self.total_bytes += other.total_bytes;
        self.physical_blocks_in_use += other.physical_blocks_in_use;
        self.physical_bytes_in_use += other.physical_bytes_in_use;
        self
    }
}

/// Latency components of one decode iteration (paper Fig. 12 categories).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepBreakdown {
    /// Model-worker (non-attention) execution time.
    pub model_s: f64,
    /// Attention-worker execution time.
    pub attn_s: f64,
    /// Network time on the critical path.
    pub network_s: f64,
    /// Scheduling/queueing overhead.
    pub sched_s: f64,
    /// End-to-end observed TBT (≤ sum of parts when overlapped).
    pub total_s: f64,
}

impl StepBreakdown {
    pub fn component_sum(&self) -> f64 {
        self.model_s + self.attn_s + self.network_s + self.sched_s
    }

    /// Fraction of component time hidden by overlapping.
    pub fn overlap_hidden_frac(&self) -> f64 {
        let sum = self.component_sum();
        if sum <= 0.0 {
            0.0
        } else {
            ((sum - self.total_s) / sum).max(0.0)
        }
    }
}

/// Aggregating recorder for a serving run.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub tokens_generated: u64,
    pub requests_completed: u64,
    pub wall_s: f64,
    tbt: Percentiles,
    batch: Welford,
    model_s: Welford,
    attn_s: Welford,
    network_s: Welford,
    sched_s: Welford,
    kv: KvCacheStats,
    kv_peak_blocks: usize,
    kv_peak_bytes: usize,
    kv_peak_physical_bytes: usize,
    wire: WireStats,
    deferred_admissions: u64,
    prefix_hits: u64,
    prefix_hit_tokens: u64,
    preemptions: u64,
    // failover aggregates: completed live recoveries in this session
    worker_deaths: u64,
    tokens_replayed: u64,
    recovery_s: Welford,
    // per-request lifecycle aggregates (request-lifecycle engine)
    queue_s: Percentiles,
    ttft_s: Percentiles,
    request_tokens: Welford,
    rejected_submissions: u64,
    // the session's KV admission budget, per worker, in both units
    kv_budget_blocks: Option<usize>,
    kv_budget_bytes: Option<usize>,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one decode iteration over `batch` requests.
    pub fn record_step(&mut self, batch: usize, bd: StepBreakdown) {
        self.tokens_generated += batch as u64;
        self.wall_s += bd.total_s;
        reg::tokens().add(batch as u64);
        reg::tbt_ns().record_secs(bd.total_s);
        self.tbt.add(bd.total_s);
        self.batch.add(batch as f64);
        self.model_s.add(bd.model_s);
        self.attn_s.add(bd.attn_s);
        self.network_s.add(bd.network_s);
        self.sched_s.add(bd.sched_s);
    }

    pub fn record_completion(&mut self, n: u64) {
        self.requests_completed += n;
        reg::requests().add(n);
    }

    /// Record a KV-arena snapshot (keeps the latest, tracks peak usage in
    /// blocks and bytes).
    pub fn record_kv(&mut self, s: KvCacheStats) {
        self.kv_peak_blocks = self.kv_peak_blocks.max(s.blocks_in_use);
        self.kv_peak_bytes = self.kv_peak_bytes.max(s.bytes_in_use);
        self.kv_peak_physical_bytes = self.kv_peak_physical_bytes.max(s.physical_bytes_in_use);
        reg::kv_blocks().set(s.blocks_in_use as i64);
        reg::kv_bytes().set(s.bytes_in_use as i64);
        reg::kv_physical_bytes().set(s.physical_bytes_in_use as i64);
        self.kv = s;
    }

    /// Latest KV-arena snapshot recorded via [`Self::record_kv`].
    pub fn kv_stats(&self) -> KvCacheStats {
        self.kv
    }

    /// Peak KV blocks in use across all recorded snapshots.
    pub fn kv_peak_blocks(&self) -> usize {
        self.kv_peak_blocks
    }

    /// Peak resident KV bytes across all recorded snapshots (dtype-aware:
    /// halves/quarters under f16/int8 block storage at the same context).
    pub fn kv_peak_bytes(&self) -> usize {
        self.kv_peak_bytes
    }

    /// Peak **physical** resident KV bytes across all recorded snapshots —
    /// the footprint after prefix-sharing dedup (≤ [`Self::kv_peak_bytes`]).
    pub fn kv_peak_physical_bytes(&self) -> usize {
        self.kv_peak_physical_bytes
    }

    /// Sum a transport endpoint's wire counters into this run's totals.
    pub fn record_wire(&mut self, s: &WireStats) {
        self.wire.merge(s);
    }

    /// Per-message-class wire traffic: logical (modelled) bytes next to
    /// measured serialized bytes (non-zero only on serializing transports).
    pub fn wire_stats(&self) -> &WireStats {
        &self.wire
    }

    /// Count one admission the KV budget deferred to a later round.
    pub fn record_deferred_admission(&mut self) {
        self.deferred_admissions += 1;
    }

    /// Admissions deferred by leader-side KV admission control.
    pub fn deferred_admissions(&self) -> u64 {
        self.deferred_admissions
    }

    /// Count one prefix-cache hit that mapped `tokens` prompt tokens from a
    /// donor request instead of re-prefilling them.
    pub fn record_prefix_hit(&mut self, tokens: usize) {
        self.prefix_hits += 1;
        self.prefix_hit_tokens += tokens as u64;
    }

    /// Admissions that mapped a shared prompt prefix.
    pub fn prefix_hits(&self) -> u64 {
        self.prefix_hits
    }

    /// Prompt tokens served from the prefix cache instead of prefill.
    pub fn prefix_hit_tokens(&self) -> u64 {
        self.prefix_hit_tokens
    }

    /// Count requests preempted back to the queue by KV pressure.
    pub fn record_preemptions(&mut self, n: u64) {
        self.preemptions += n;
        reg::preemptions().add(n);
    }

    /// Requests preempted by overcommit pressure relief.
    pub fn preemptions(&self) -> u64 {
        self.preemptions
    }

    /// Record a completed worker-death recovery: the replacement is up,
    /// every live request was preempted for replay (`tokens_replayed` =
    /// Σ effective-prompt lengths re-prefilled), and serving resumed
    /// after `recovery_s` seconds.
    pub fn record_recovery(&mut self, tokens_replayed: u64, recovery_s: f64) {
        self.worker_deaths += 1;
        self.tokens_replayed += tokens_replayed;
        self.recovery_s.add(recovery_s);
        reg::recoveries().inc();
        reg::tokens_replayed().add(tokens_replayed);
        reg::recovery_ns().record_secs(recovery_s);
    }

    /// Worker deaths recovered from in this session.
    pub fn worker_deaths(&self) -> u64 {
        self.worker_deaths
    }

    /// Tokens re-prefilled by recovery replays in this session.
    pub fn tokens_replayed(&self) -> u64 {
        self.tokens_replayed
    }

    /// Mean seconds per recovery (0 when none happened).
    pub fn mean_recovery_s(&self) -> f64 {
        self.recovery_s.mean()
    }

    /// Record one completed request's lifecycle: queueing delay (submit →
    /// admission), TTFT (submit → first generated token, when one exists),
    /// and its output token count.
    pub fn record_request(&mut self, queue_s: f64, ttft_s: Option<f64>, tokens: u64) {
        self.queue_s.add(queue_s);
        reg::queue_ns().record_secs(queue_s);
        if let Some(t) = ttft_s {
            self.ttft_s.add(t);
            reg::ttft_ns().record_secs(t);
        }
        self.request_tokens.add(tokens as f64);
    }

    /// Mean submit→admission delay across completed requests.
    pub fn mean_queue_s(&self) -> f64 {
        if self.queue_s.is_empty() { 0.0 } else { self.queue_s.mean() }
    }

    /// Mean submit→first-token latency across completed requests.
    pub fn mean_ttft_s(&self) -> f64 {
        if self.ttft_s.is_empty() { 0.0 } else { self.ttft_s.mean() }
    }

    /// Queueing-delay percentiles across completed requests (NaN when no
    /// request completed — callers guard before printing).
    pub fn p50_queue_s(&mut self) -> f64 {
        self.queue_s.p50()
    }

    pub fn p95_queue_s(&mut self) -> f64 {
        self.queue_s.p95()
    }

    pub fn p99_queue_s(&mut self) -> f64 {
        self.queue_s.p99()
    }

    /// TTFT percentiles across completed requests that generated a token
    /// (NaN when none did).
    pub fn p50_ttft_s(&mut self) -> f64 {
        self.ttft_s.p50()
    }

    pub fn p95_ttft_s(&mut self) -> f64 {
        self.ttft_s.p95()
    }

    pub fn p99_ttft_s(&mut self) -> f64 {
        self.ttft_s.p99()
    }

    /// Mean output tokens per completed request.
    pub fn mean_request_tokens(&self) -> f64 {
        self.request_tokens.mean()
    }

    /// Count one request rejected with a typed `SubmitError` (the run
    /// continues — rejection is per request, not per session).
    pub fn record_rejection(&mut self) {
        self.rejected_submissions += 1;
        reg::rejected().inc();
    }

    /// Requests rejected at submit time.
    pub fn rejected_submissions(&self) -> u64 {
        self.rejected_submissions
    }

    /// Record the session's per-worker KV admission budget in both units
    /// (whichever unit the budget was given in, the other is derived from
    /// the workers' dtype-aware per-block byte size).
    pub fn set_kv_budget(&mut self, blocks: Option<usize>, bytes: Option<usize>) {
        self.kv_budget_blocks = blocks;
        self.kv_budget_bytes = bytes;
    }

    /// The session's KV budget in blocks per worker (if budgeted).
    pub fn kv_budget_blocks(&self) -> Option<usize> {
        self.kv_budget_blocks
    }

    /// The session's KV budget in bytes per worker (if budgeted).
    pub fn kv_budget_bytes(&self) -> Option<usize> {
        self.kv_budget_bytes
    }

    /// Aggregate throughput in tokens/second.
    pub fn throughput(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.wall_s
        }
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch.mean()
    }

    pub fn mean_tbt(&self) -> f64 {
        self.tbt.mean()
    }

    pub fn p99_tbt(&mut self) -> f64 {
        self.tbt.p99()
    }

    pub fn p95_tbt(&mut self) -> f64 {
        self.tbt.p95()
    }

    pub fn p50_tbt(&mut self) -> f64 {
        self.tbt.p50()
    }

    /// Refresh the registry's end-of-session gauge view (KV occupancy and
    /// peaks). Counters and histograms stream at `record_*` time; gauges
    /// for peak values only settle once the session drains, so the leader
    /// calls this from `drain()` before handing the metrics out.
    pub fn publish_registry(&self) {
        reg::kv_blocks().set(self.kv.blocks_in_use as i64);
        reg::kv_bytes().set(self.kv.bytes_in_use as i64);
        reg::kv_physical_bytes().set(self.kv.physical_bytes_in_use as i64);
        reg::kv_peak_blocks().set(self.kv_peak_blocks as i64);
        reg::kv_peak_bytes().set(self.kv_peak_bytes as i64);
    }

    pub fn steps(&self) -> u64 {
        self.batch.count()
    }

    /// Mean per-component breakdown across recorded steps.
    pub fn mean_breakdown(&self) -> StepBreakdown {
        StepBreakdown {
            model_s: self.model_s.mean(),
            attn_s: self.attn_s.mean(),
            network_s: self.network_s.mean(),
            sched_s: self.sched_s.mean(),
            total_s: self.tbt.mean(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bd(model: f64, attn: f64, net: f64, total: f64) -> StepBreakdown {
        StepBreakdown { model_s: model, attn_s: attn, network_s: net, sched_s: 0.0, total_s: total }
    }

    #[test]
    fn throughput_tokens_over_wall() {
        let mut m = ServeMetrics::new();
        for _ in 0..10 {
            m.record_step(32, bd(0.01, 0.005, 0.002, 0.02));
        }
        assert_eq!(m.tokens_generated, 320);
        assert!((m.throughput() - 320.0 / 0.2).abs() < 1e-9);
        assert!((m.mean_batch() - 32.0).abs() < 1e-12);
    }

    #[test]
    fn breakdown_averages() {
        let mut m = ServeMetrics::new();
        m.record_step(1, bd(0.010, 0.004, 0.002, 0.014));
        m.record_step(1, bd(0.020, 0.008, 0.004, 0.028));
        let b = m.mean_breakdown();
        assert!((b.model_s - 0.015).abs() < 1e-12);
        assert!((b.attn_s - 0.006).abs() < 1e-12);
        assert!((b.total_s - 0.021).abs() < 1e-12);
    }

    #[test]
    fn overlap_hidden_fraction() {
        // components sum to 16 ms but observed TBT is 14 ms → 12.5 % hidden
        let b = bd(0.010, 0.004, 0.002, 0.014);
        assert!((b.overlap_hidden_frac() - 0.125).abs() < 1e-9);
        // no overlap
        let b2 = bd(0.010, 0.004, 0.002, 0.016);
        assert_eq!(b2.overlap_hidden_frac(), 0.0);
    }

    #[test]
    fn tbt_percentiles() {
        let mut m = ServeMetrics::new();
        for i in 1..=100 {
            m.record_step(1, bd(0.0, 0.0, 0.0, i as f64 * 1e-3));
        }
        assert!((m.p50_tbt() - 0.0505).abs() < 1e-4);
        assert!(m.p99_tbt() > 0.098);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.steps(), 0);
        assert_eq!(m.kv_stats(), KvCacheStats::default());
        assert_eq!(m.kv_peak_blocks(), 0);
        assert_eq!(m.wire_stats().total().msgs, 0);
        assert_eq!(m.deferred_admissions(), 0);
        assert_eq!(m.rejected_submissions(), 0);
        assert_eq!(m.mean_queue_s(), 0.0);
        assert_eq!(m.mean_ttft_s(), 0.0);
        assert_eq!(m.kv_budget_blocks(), None);
        assert_eq!(m.kv_budget_bytes(), None);
    }

    #[test]
    fn request_lifecycle_aggregates() {
        let mut m = ServeMetrics::new();
        m.record_request(0.010, Some(0.030), 4);
        m.record_request(0.030, None, 8); // cancelled-before-first-token shape
        m.record_request(0.020, Some(0.050), 6);
        assert!((m.mean_queue_s() - 0.020).abs() < 1e-12);
        assert!((m.mean_ttft_s() - 0.040).abs() < 1e-12); // only the Some()s
        assert!((m.mean_request_tokens() - 6.0).abs() < 1e-12);
        m.record_rejection();
        m.record_rejection();
        assert_eq!(m.rejected_submissions(), 2);
        m.set_kv_budget(Some(4), Some(4 * 4096));
        assert_eq!(m.kv_budget_blocks(), Some(4));
        assert_eq!(m.kv_budget_bytes(), Some(16384));
    }

    #[test]
    fn request_lifecycle_percentiles() {
        let mut m = ServeMetrics::new();
        for i in 1..=100 {
            m.record_request(i as f64 * 1e-3, Some(i as f64 * 2e-3), 4);
        }
        assert!((m.p50_queue_s() - 0.0505).abs() < 1e-4);
        assert!(m.p99_queue_s() > 0.098);
        assert!((m.p95_ttft_s() - 0.1901).abs() < 1e-4);
        // no steps recorded → TBT percentiles are NaN, means stay 0-guarded
        assert!(m.p95_tbt().is_nan());
        assert!((m.mean_queue_s() - 0.0505).abs() < 1e-4);
    }

    #[test]
    fn wire_and_deferral_accounting() {
        use crate::net::MsgClass;
        let mut m = ServeMetrics::new();
        let mut w = WireStats::new();
        w.record(MsgClass::StepKv, 1000, 1040);
        m.record_wire(&w);
        m.record_wire(&w);
        let c = m.wire_stats().class(MsgClass::StepKv);
        assert_eq!((c.msgs, c.logical_bytes, c.serialized_bytes), (2, 2000, 2080));
        m.record_deferred_admission();
        assert_eq!(m.deferred_admissions(), 1);
    }

    #[test]
    fn kv_stats_latest_and_peak() {
        let mut m = ServeMetrics::new();
        m.record_kv(KvCacheStats {
            blocks_in_use: 10,
            total_blocks: 16,
            block_size: 16,
            internal_waste_tokens: 5,
            bytes_in_use: 10 * 4096,
            total_bytes: 16 * 4096,
            physical_blocks_in_use: 6,
            physical_bytes_in_use: 6 * 4096,
        });
        m.record_kv(KvCacheStats {
            blocks_in_use: 3,
            total_blocks: 16,
            block_size: 16,
            internal_waste_tokens: 1,
            bytes_in_use: 3 * 4096,
            total_bytes: 16 * 4096,
            physical_blocks_in_use: 3,
            physical_bytes_in_use: 3 * 4096,
        });
        assert_eq!(m.kv_stats().blocks_in_use, 3);
        assert_eq!(m.kv_peak_blocks(), 10);
        assert_eq!(m.kv_peak_bytes(), 10 * 4096);
        assert_eq!(m.kv_peak_physical_bytes(), 6 * 4096, "peak tracks the deduped view");
        assert_eq!(m.kv_stats().physical_blocks_in_use, 3);
        assert_eq!(m.kv_stats().bytes_in_use, 3 * 4096);
        assert!((m.kv_stats().utilization() - 3.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn kv_stats_merge_sums_pools() {
        let a = KvCacheStats {
            blocks_in_use: 4,
            total_blocks: 8,
            block_size: 16,
            internal_waste_tokens: 2,
            bytes_in_use: 4 * 1056,
            total_bytes: 8 * 1056,
            physical_blocks_in_use: 2,
            physical_bytes_in_use: 2 * 1056,
        };
        let b = KvCacheStats {
            blocks_in_use: 1,
            total_blocks: 8,
            block_size: 16,
            internal_waste_tokens: 7,
            bytes_in_use: 1056,
            total_bytes: 8 * 1056,
            physical_blocks_in_use: 1,
            physical_bytes_in_use: 1056,
        };
        let m = a.merge(&b);
        assert_eq!(m.blocks_in_use, 5);
        assert_eq!(m.total_blocks, 16);
        assert_eq!(m.internal_waste_tokens, 9);
        assert_eq!(m.block_size, 16);
        assert_eq!(m.bytes_in_use, 5 * 1056);
        assert_eq!(m.total_bytes, 16 * 1056);
        assert_eq!(m.physical_blocks_in_use, 3);
        assert_eq!(m.physical_bytes_in_use, 3 * 1056);
    }
}
