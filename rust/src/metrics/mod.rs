//! Serving metrics: token throughput, time-between-tokens (TBT), batch-size
//! tracking, and the per-component latency breakdown of Fig. 12.

use crate::util::stats::{Percentiles, Welford};

/// Latency components of one decode iteration (paper Fig. 12 categories).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct StepBreakdown {
    /// Model-worker (non-attention) execution time.
    pub model_s: f64,
    /// Attention-worker execution time.
    pub attn_s: f64,
    /// Network time on the critical path.
    pub network_s: f64,
    /// Scheduling/queueing overhead.
    pub sched_s: f64,
    /// End-to-end observed TBT (≤ sum of parts when overlapped).
    pub total_s: f64,
}

impl StepBreakdown {
    pub fn component_sum(&self) -> f64 {
        self.model_s + self.attn_s + self.network_s + self.sched_s
    }

    /// Fraction of component time hidden by overlapping.
    pub fn overlap_hidden_frac(&self) -> f64 {
        let sum = self.component_sum();
        if sum <= 0.0 {
            0.0
        } else {
            ((sum - self.total_s) / sum).max(0.0)
        }
    }
}

/// Aggregating recorder for a serving run.
#[derive(Debug, Default)]
pub struct ServeMetrics {
    pub tokens_generated: u64,
    pub requests_completed: u64,
    pub wall_s: f64,
    tbt: Percentiles,
    batch: Welford,
    model_s: Welford,
    attn_s: Welford,
    network_s: Welford,
    sched_s: Welford,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one decode iteration over `batch` requests.
    pub fn record_step(&mut self, batch: usize, bd: StepBreakdown) {
        self.tokens_generated += batch as u64;
        self.wall_s += bd.total_s;
        self.tbt.add(bd.total_s);
        self.batch.add(batch as f64);
        self.model_s.add(bd.model_s);
        self.attn_s.add(bd.attn_s);
        self.network_s.add(bd.network_s);
        self.sched_s.add(bd.sched_s);
    }

    pub fn record_completion(&mut self, n: u64) {
        self.requests_completed += n;
    }

    /// Aggregate throughput in tokens/second.
    pub fn throughput(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.wall_s
        }
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch.mean()
    }

    pub fn mean_tbt(&self) -> f64 {
        self.tbt.mean()
    }

    pub fn p99_tbt(&mut self) -> f64 {
        self.tbt.p99()
    }

    pub fn p50_tbt(&mut self) -> f64 {
        self.tbt.p50()
    }

    pub fn steps(&self) -> u64 {
        self.batch.count()
    }

    /// Mean per-component breakdown across recorded steps.
    pub fn mean_breakdown(&self) -> StepBreakdown {
        StepBreakdown {
            model_s: self.model_s.mean(),
            attn_s: self.attn_s.mean(),
            network_s: self.network_s.mean(),
            sched_s: self.sched_s.mean(),
            total_s: self.tbt.mean(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bd(model: f64, attn: f64, net: f64, total: f64) -> StepBreakdown {
        StepBreakdown { model_s: model, attn_s: attn, network_s: net, sched_s: 0.0, total_s: total }
    }

    #[test]
    fn throughput_tokens_over_wall() {
        let mut m = ServeMetrics::new();
        for _ in 0..10 {
            m.record_step(32, bd(0.01, 0.005, 0.002, 0.02));
        }
        assert_eq!(m.tokens_generated, 320);
        assert!((m.throughput() - 320.0 / 0.2).abs() < 1e-9);
        assert!((m.mean_batch() - 32.0).abs() < 1e-12);
    }

    #[test]
    fn breakdown_averages() {
        let mut m = ServeMetrics::new();
        m.record_step(1, bd(0.010, 0.004, 0.002, 0.014));
        m.record_step(1, bd(0.020, 0.008, 0.004, 0.028));
        let b = m.mean_breakdown();
        assert!((b.model_s - 0.015).abs() < 1e-12);
        assert!((b.attn_s - 0.006).abs() < 1e-12);
        assert!((b.total_s - 0.021).abs() < 1e-12);
    }

    #[test]
    fn overlap_hidden_fraction() {
        // components sum to 16 ms but observed TBT is 14 ms → 12.5 % hidden
        let b = bd(0.010, 0.004, 0.002, 0.014);
        assert!((b.overlap_hidden_frac() - 0.125).abs() < 1e-9);
        // no overlap
        let b2 = bd(0.010, 0.004, 0.002, 0.016);
        assert_eq!(b2.overlap_hidden_frac(), 0.0);
    }

    #[test]
    fn tbt_percentiles() {
        let mut m = ServeMetrics::new();
        for i in 1..=100 {
            m.record_step(1, bd(0.0, 0.0, 0.0, i as f64 * 1e-3));
        }
        assert!((m.p50_tbt() - 0.0505).abs() < 1e-4);
        assert!(m.p99_tbt() > 0.098);
    }

    #[test]
    fn empty_metrics_safe() {
        let m = ServeMetrics::new();
        assert_eq!(m.throughput(), 0.0);
        assert_eq!(m.steps(), 0);
    }
}
