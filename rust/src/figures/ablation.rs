//! Ablations beyond the paper's headline figures:
//!
//! * `fig9` — head-level vs request-level attention partitioning under the
//!   real trace length distributions (the paper argues Fig. 9
//!   qualitatively; we quantify the load imbalance and its TBT impact).
//! * `offload` — §7 "generality": operator-level offloading economics for
//!   LoRA and MoE expert FFNs, using the same roofline + network models.

use crate::devices::roofline::atime_tokens;
use crate::devices::specs::{H100, H20, LLAMA3_70B, LLAMA_65B};
use crate::kvcache::partition::{head_level, request_level};
use crate::netsim::stack::{FHBN, LINE_RATE_400G};
use crate::trace::{synthesize, ALL_TRACES};
use crate::util::json::Json;

/// Fig. 9 ablation: partitioning strategy load imbalance → attention-time
/// inflation (the slowest worker gates the layer).
pub fn fig9(n_requests: usize, seed: u64) -> Json {
    println!("Fig. 9 ablation: attention work partitioning (8 workers)");
    println!(
        "{:<11} {:>7} {:>16} {:>16} {:>12}",
        "trace", "batch", "head imbalance", "req imbalance", "TBT penalty"
    );
    let workers = 8;
    let mut rows = Vec::new();
    for t in ALL_TRACES {
        let reqs = synthesize(t, n_requests, seed);
        // a representative decode batch: first `batch` requests' contexts
        let batch = 16.min(reqs.len());
        let lens: Vec<usize> = reqs[..batch].iter().map(|r| r.max_context()).collect();
        let kvb = LLAMA_65B.kv_bytes_per_token();
        let head = head_level(8, workers, &lens, kvb / 8.0).unwrap();
        let req = request_level(workers, &lens, kvb).unwrap();
        // the layer finishes when the most-loaded worker does
        let penalty = (1.0 + req.imbalance()) / (1.0 + head.imbalance());
        println!(
            "{:<11} {:>7} {:>15.2}% {:>15.2}% {:>11.2}×",
            t.name,
            batch,
            head.imbalance() * 100.0,
            req.imbalance() * 100.0,
            penalty
        );
        rows.push(Json::obj(vec![
            ("trace", Json::str(t.name)),
            ("head_imbalance", Json::num(head.imbalance())),
            ("request_imbalance", Json::num(req.imbalance())),
            ("tbt_penalty", Json::num(penalty)),
        ]));
    }
    Json::obj(vec![("figure", Json::str("9-ablation")), ("rows", Json::arr(rows))])
}

/// §7 generality: would offloading a low-intensity operator to the cheap
/// memory pool pay off? Computes the break-even network time vs the compute
/// saved, for LoRA adapters and MoE expert FFNs.
pub fn offload_analysis() -> Json {
    println!("§7 extension: operator-level offloading economics (per layer, per token)");
    println!(
        "{:<22} {:>12} {:>12} {:>12} {:>9}",
        "operator", "H100 time", "H20 time", "net time", "verdict"
    );
    let d = LLAMA3_70B.d as f64;
    let e = 2.0f64;
    let mut rows = Vec::new();
    // (name, flops per token, bytes read per token, transfer bytes per token)
    let lora_r = 64.0;
    let experts_active = 2.0;
    let ffn = 3.5 * d;
    let cases = [
        ("LoRA adapter (r=64)", 4.0 * d * lora_r, 2.0 * e * d * lora_r, 2.0 * e * d),
        (
            "MoE expert FFN (k=2)",
            experts_active * 6.0 * d * ffn / 8.0, // 1/8 batch density per expert
            experts_active * 3.0 * e * d * ffn,
            2.0 * e * d,
        ),
        ("attention (B=128, l=4k)", 128.0 * 4.0 * d * 4096.0,
         128.0 * 2.0 * e * d * 4096.0 / 8.0, 128.0 * 2.25 * e * d),
    ];
    for (name, flops, bytes, wire) in cases {
        let t_h100 = (flops / H100.eff_flops()).max(bytes / H100.eff_bw());
        let t_h20 = (flops / H20.eff_flops()).max(bytes / H20.eff_bw());
        let t_net = FHBN.one_way(wire, LINE_RATE_400G) * 2.0;
        // offload pays when cheap-device time + wire < giving up H100 time,
        // valued at the price ratio (the paper's cost argument)
        let cost_h100 = t_h100 * H100.price_hr;
        let cost_off = t_h20 * H20.price_hr;
        let worthwhile = cost_off < cost_h100 && t_h20 + t_net < 3.0 * t_h100;
        println!(
            "{:<22} {:>12} {:>12} {:>12} {:>9}",
            name,
            crate::util::stats::fmt_duration(t_h100),
            crate::util::stats::fmt_duration(t_h20),
            crate::util::stats::fmt_duration(t_net),
            if worthwhile { "offload" } else { "keep" }
        );
        rows.push(Json::obj(vec![
            ("operator", Json::str(name)),
            ("t_h100", Json::num(t_h100)),
            ("t_h20", Json::num(t_h20)),
            ("t_net", Json::num(t_net)),
            ("offload", Json::Bool(worthwhile)),
        ]));
    }
    Json::obj(vec![("analysis", Json::str("offload")), ("rows", Json::arr(rows))])
}

/// §7 alternative memory devices: attention time per device class,
/// including a PIM-class device and CPU-DRAM with sparse attention.
pub fn alt_devices() -> Json {
    use crate::devices::specs::DeviceSpec;
    const PIM: DeviceSpec = DeviceSpec {
        name: "PIM-stack",
        bf16_tflops: 32.0,
        mem_gib: 128.0,
        mem_bw_tbs: 8.0,
        power_w: 150.0,
        ici_gbs: 0.0,
        net_gbps: 200.0,
        price_hr: 1.80,
        gemm_eff: 0.5,
        bw_eff: 0.9,
    };
    const CPU_DRAM: DeviceSpec = DeviceSpec {
        name: "CPU-DRAM",
        bf16_tflops: 4.0,
        mem_gib: 1024.0,
        mem_bw_tbs: 0.4,
        power_w: 350.0,
        ici_gbs: 0.0,
        net_gbps: 200.0,
        price_hr: 1.20,
        gemm_eff: 0.5,
        bw_eff: 0.8,
    };
    println!("§7 extension: attention worker device alternatives (70B, B=128, l=8k)");
    println!("{:<10} {:>12} {:>16} {:>14}", "device", "atime", "tokens/s/$ (att)", "KV cap (GiB)");
    let tokens = 128.0 * 8192.0;
    let mut rows = Vec::new();
    for (dev, sparse_keep) in [(&H20, 1.0), (&PIM, 1.0), (&CPU_DRAM, 0.25)] {
        // CPU-DRAM path assumes sparse attention keeping 25 % of KV reads
        // (paper: "preferable to also adopt sparse attention mechanisms")
        let c = atime_tokens(&LLAMA3_70B, dev, tokens * sparse_keep, 1);
        let tps_per_dollar = 128.0 / c.time_s * 3600.0 / dev.price_hr;
        println!(
            "{:<10} {:>12} {:>16.0} {:>14.0}",
            dev.name,
            crate::util::stats::fmt_duration(c.time_s),
            tps_per_dollar,
            dev.mem_gib
        );
        rows.push(Json::obj(vec![
            ("device", Json::str(dev.name)),
            ("atime_s", Json::num(c.time_s)),
            ("tps_per_dollar", Json::num(tps_per_dollar)),
            ("sparse_keep", Json::num(sparse_keep)),
        ]));
    }
    Json::obj(vec![("analysis", Json::str("alt-devices")), ("rows", Json::arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig9_head_level_always_balanced() {
        let f = fig9(500, 3);
        for r in f.get("rows").as_arr().unwrap() {
            assert!(r.get("head_imbalance").as_f64().unwrap() < 1e-9);
            assert!(r.get("request_imbalance").as_f64().unwrap() >= 0.0);
            assert!(r.get("tbt_penalty").as_f64().unwrap() >= 1.0);
        }
    }

    #[test]
    fn fig9_long_traces_worse_for_request_level() {
        // Kimi traces (heavy-tailed 8–12k contexts) should show material
        // request-level imbalance.
        let f = fig9(800, 5);
        let kimi_pen: f64 = f
            .get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|r| r.get("trace").as_str().unwrap().starts_with("Kimi"))
            .map(|r| r.get("tbt_penalty").as_f64().unwrap())
            .fold(0.0, f64::max);
        assert!(kimi_pen > 1.05, "penalty {kimi_pen}");
    }

    #[test]
    fn offload_attention_always_wins() {
        let j = offload_analysis();
        let attn = j
            .get("rows")
            .as_arr()
            .unwrap()
            .iter()
            .find(|r| r.get("operator").as_str().unwrap().contains("attention"))
            .unwrap();
        assert_eq!(attn.get("offload").as_bool(), Some(true));
    }

    #[test]
    fn alt_devices_pim_most_cost_effective() {
        let j = alt_devices();
        let rows = j.get("rows").as_arr().unwrap();
        let get = |name: &str| {
            rows.iter()
                .find(|r| r.get("device").as_str() == Some(name))
                .unwrap()
                .get("tps_per_dollar")
                .as_f64()
                .unwrap()
        };
        assert!(get("PIM-stack") > get("H20"), "PIM should beat H20 per dollar");
    }
}
