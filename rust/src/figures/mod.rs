//! Figure/table harnesses — regenerate every evaluation artifact of the
//! paper (see DESIGN.md §5 for the experiment index and EXPERIMENTS.md for
//! recorded paper-vs-measured comparisons).

pub mod ablation;
pub mod analysis;
pub mod experiments;
pub mod network;
pub mod serving;

pub use experiments::{run, save, ALL_IDS};
