//! Experiment registry: maps every paper table/figure id to its harness and
//! persists results under `results/`.

use std::path::Path;

use crate::util::json::Json;

/// Run one experiment by id; returns its JSON record.
/// `n_requests` bounds trace sizes for the serving simulations.
pub fn run(id: &str, n_requests: usize, seed: u64) -> Result<Json, String> {
    let j = match id {
        "table1" => super::analysis::table1(),
        "fig2" => super::analysis::fig2(),
        "fig3" => super::analysis::fig3(),
        "fig4" => super::analysis::fig4(0.2),
        "table3" => super::serving::table3(),
        "table4" => super::serving::table4(n_requests.max(2000), seed),
        "table5" => super::serving::table5(),
        "fig10" => super::serving::fig10(n_requests, seed),
        "fig11" => super::serving::fig11(n_requests, seed),
        "fig12" => super::serving::fig12(),
        "fig13" => super::network::fig13(),
        "fig14" => super::serving::fig14(),
        "fig9" => super::ablation::fig9(n_requests.max(500), seed),
        "offload" => super::ablation::offload_analysis(),
        "alt-devices" => super::ablation::alt_devices(),
        "slo" => super::serving::slo_sweep(n_requests, seed),
        "pingpong-live" => super::network::live_pingpong(65536, 50),
        other => return Err(format!("unknown experiment '{other}'")),
    };
    Ok(j)
}

/// Every experiment id, in paper order.
pub const ALL_IDS: &[&str] = &[
    "table1", "fig2", "fig3", "fig4", "table3", "table4", "table5",
    "fig10", "fig11", "fig12", "fig13", "fig14", "fig9", "offload", "alt-devices", "slo",
];

/// Persist an experiment record to `results/<id>.json`.
pub fn save(id: &str, j: &Json, results_dir: impl AsRef<Path>) -> std::io::Result<()> {
    let dir = results_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{id}.json")), j.pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_ids_runnable() {
        for id in ALL_IDS {
            // small request counts keep this test quick
            if matches!(*id, "fig10" | "fig11") {
                continue; // covered by their own (heavier) tests
            }
            let j = run(id, 200, 3).unwrap();
            assert!(!j.is_null());
        }
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("fig99", 10, 0).is_err());
    }

    #[test]
    fn save_roundtrip() {
        let dir = std::env::temp_dir().join("lamina-results-test");
        let j = run("table1", 10, 0).unwrap();
        save("table1", &j, &dir).unwrap();
        let text = std::fs::read_to_string(dir.join("table1.json")).unwrap();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}
