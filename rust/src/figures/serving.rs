//! Serving figures & tables (paper §6): Tables 3–5, Fig. 10 (equal-cost
//! throughput/TBT/batch), Fig. 11 (DOP/TP sweep vs cost), Fig. 12 (latency
//! breakdown), Fig. 14 (overlap ablation).

use crate::baseline::vllm::{run_vllm, VllmConfig};
use crate::coordinator::planner::{best_cost_efficiency, sweep_lamina_dops, sweep_vllm_tps, table5_configs};
use crate::coordinator::sim::{run_lamina, wave_cost, LaminaConfig};
use crate::devices::specs::{LlmSpec, ALL_MODELS, H100, H20, LLAMA3_70B, LLAMA_65B};
use crate::netsim::stack::FHBN;
use crate::trace::{synthesize, ALL_TRACES};
use crate::util::json::Json;

/// Table 3: evaluated models.
pub fn table3() -> Json {
    println!("Table 3: evaluated LLMs");
    println!("{:<12} {:>10} {:>4} {:>6} {:>3}", "model", "params GB", "L", "d", "G");
    let mut rows = Vec::new();
    for m in ALL_MODELS {
        println!(
            "{:<12} {:>10.1} {:>4} {:>6} {:>3}",
            m.name,
            m.param_bytes() / 1e9,
            m.layers,
            m.d,
            m.gqa_group
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(m.name)),
            ("param_gb", Json::num(m.param_bytes() / 1e9)),
            ("layers", Json::num(m.layers as f64)),
            ("d", Json::num(m.d as f64)),
            ("g", Json::num(m.gqa_group as f64)),
        ]));
    }
    Json::obj(vec![("table", Json::str("3")), ("rows", Json::arr(rows))])
}

/// Table 4: trace statistics (spec + a synthesized sample's empirical fit).
pub fn table4(sample_n: usize, seed: u64) -> Json {
    println!("Table 4: request traces (synthetic fit vs published stats)");
    println!(
        "{:<11} {:>9} {:>9} {:>9} {:>10} {:>10}",
        "trace", "#req", "l_p", "l_g", "fit l_p", "fit l_g"
    );
    let mut rows = Vec::new();
    for t in ALL_TRACES {
        let reqs = synthesize(t, sample_n, seed);
        let s = crate::trace::summarize(&reqs);
        println!(
            "{:<11} {:>9} {:>9.1} {:>9.1} {:>10.1} {:>10.1}",
            t.name, t.requests, t.mean_prompt, t.mean_gen, s.mean_prompt, s.mean_gen
        );
        rows.push(Json::obj(vec![
            ("trace", Json::str(t.name)),
            ("requests", Json::num(t.requests as f64)),
            ("mean_prompt", Json::num(t.mean_prompt)),
            ("mean_gen", Json::num(t.mean_gen)),
            ("fit_prompt", Json::num(s.mean_prompt)),
            ("fit_gen", Json::num(s.mean_gen)),
        ]));
    }
    Json::obj(vec![("table", Json::str("4")), ("rows", Json::arr(rows))])
}

/// Table 5: equal-cost configurations.
pub fn table5() -> Json {
    println!("Table 5: equal-cost hardware configurations");
    println!("{:<12} {:>14} {:>10} {:>10} {:>10}", "model", "Lamina DOP", "$/hr", "vLLM", "$/hr");
    let mut rows = Vec::new();
    for m in ALL_MODELS {
        let (dop, tp) = table5_configs(m);
        let lamina = LaminaConfig::standard(m, &H100, &H20, dop, &FHBN);
        let vllm = VllmConfig::standard(m, &H100, tp);
        println!(
            "{:<12} {:>10}({},{}) {:>10.2} {:>7}×H100 {:>10.2}",
            m.name, "DOP=", dop.0, dop.1, lamina.cost_per_hour(), tp, vllm.cost_per_hour()
        );
        rows.push(Json::obj(vec![
            ("model", Json::str(m.name)),
            ("dop_a", Json::num(dop.0 as f64)),
            ("dop_b", Json::num(dop.1 as f64)),
            ("lamina_cost", Json::num(lamina.cost_per_hour())),
            ("vllm_tp", Json::num(tp as f64)),
            ("vllm_cost", Json::num(vllm.cost_per_hour())),
        ]));
    }
    Json::obj(vec![("table", Json::str("5")), ("rows", Json::arr(rows))])
}

/// Fig. 10: Lamina vs vLLM at equal cost over all models × traces.
/// `n_requests` subsamples each trace (distribution-preserving).
pub fn fig10(n_requests: usize, seed: u64) -> Json {
    println!("Fig. 10: serving performance at equal hardware cost ({n_requests} requests/trace)");
    println!(
        "{:<12} {:<11} {:>12} {:>12} {:>8} {:>9} {:>9} {:>8} {:>7}",
        "model", "trace", "lamina tok/s", "vllm tok/s", "speedup", "lam TBT", "vllm TBT", "lam B", "vllm B"
    );
    let mut rows = Vec::new();
    let mut wins = Vec::new();
    let mut batch_ratios = Vec::new();
    for model in ALL_MODELS {
        for t in ALL_TRACES {
            let reqs = synthesize(t, n_requests, seed);
            let (dop, tp) = table5_configs(model);
            let lam_cfg = LaminaConfig::standard(model, &H100, &H20, dop, &FHBN);
            let vll_cfg = VllmConfig::standard(model, &H100, tp);
            let lam = run_lamina(&lam_cfg, &reqs);
            let vll = run_vllm(&vll_cfg, &reqs);
            let speedup = lam.metrics.throughput() / vll.metrics.throughput();
            wins.push(speedup);
            batch_ratios.push(lam.metrics.mean_batch() / vll.metrics.mean_batch());
            println!(
                "{:<12} {:<11} {:>12.0} {:>12.0} {:>7.2}× {:>9} {:>9} {:>8.0} {:>7.0}",
                model.name,
                t.name,
                lam.metrics.throughput(),
                vll.metrics.throughput(),
                speedup,
                crate::util::stats::fmt_duration(lam.metrics.mean_tbt()),
                crate::util::stats::fmt_duration(vll.metrics.mean_tbt()),
                lam.metrics.mean_batch(),
                vll.metrics.mean_batch()
            );
            rows.push(Json::obj(vec![
                ("model", Json::str(model.name)),
                ("trace", Json::str(t.name)),
                ("lamina_tps", Json::num(lam.metrics.throughput())),
                ("vllm_tps", Json::num(vll.metrics.throughput())),
                ("speedup", Json::num(speedup)),
                ("lamina_tbt", Json::num(lam.metrics.mean_tbt())),
                ("vllm_tbt", Json::num(vll.metrics.mean_tbt())),
                ("lamina_batch", Json::num(lam.metrics.mean_batch())),
                ("vllm_batch", Json::num(vll.metrics.mean_batch())),
            ]));
        }
    }
    let min_win = wins.iter().cloned().fold(f64::INFINITY, f64::min);
    let max_win = wins.iter().cloned().fold(0.0, f64::max);
    let mean_batch_ratio = batch_ratios.iter().sum::<f64>() / batch_ratios.len() as f64;
    println!(
        "=> throughput gain: {:.1}% – {:.1}% (paper: 16.1–90.1%); mean batch ratio {:.2}× (paper: 2.39×)",
        (min_win - 1.0) * 100.0,
        (max_win - 1.0) * 100.0,
        mean_batch_ratio
    );
    Json::obj(vec![
        ("figure", Json::str("10")),
        ("rows", Json::arr(rows)),
        ("min_gain", Json::num(min_win - 1.0)),
        ("max_gain", Json::num(max_win - 1.0)),
        ("mean_batch_ratio", Json::num(mean_batch_ratio)),
    ])
}

/// Fig. 11: throughput vs hourly cost across hardware configurations.
pub fn fig11(n_requests: usize, seed: u64) -> Json {
    println!("Fig. 11: decoding throughput vs hardware cost");
    let mut out_rows = Vec::new();
    for model in ALL_MODELS {
        let trace = &crate::trace::AZURE_CONV;
        let reqs = synthesize(trace, n_requests, seed);
        let min_a = if model.param_bytes() > H100.mem_bytes() { 2 } else { 1 };
        let dops: Vec<(usize, usize)> = [(1usize, 1usize), (1, 2), (1, 3), (2, 2), (2, 4), (2, 6), (2, 8)]
            .iter()
            .copied()
            .filter(|&(a, _)| a >= min_a)
            .collect();
        let lam = sweep_lamina_dops(model, &H100, &H20, &FHBN, &dops, &reqs);
        let vll = sweep_vllm_tps(model, &H100, &[1, 2, 4, 8], &reqs);
        println!("-- {} ({})", model.name, trace.name);
        println!("{:<14} {:>9} {:>12} {:>14}", "config", "$/hr", "tok/s", "tok/$");
        for p in lam.iter().chain(vll.iter()) {
            println!(
                "{:<14} {:>9.2} {:>12.0} {:>14.0}",
                p.label, p.cost_hr, p.throughput_tps, p.tokens_per_dollar
            );
            out_rows.push(Json::obj(vec![
                ("model", Json::str(model.name)),
                ("config", Json::str(p.label.clone())),
                ("cost_hr", Json::num(p.cost_hr)),
                ("tps", Json::num(p.throughput_tps)),
                ("tokens_per_dollar", Json::num(p.tokens_per_dollar)),
            ]));
        }
        if let Some(best) = best_cost_efficiency(&lam) {
            println!("   best Lamina efficiency: {}", best.label);
        }
        if let Some(best) = best_cost_efficiency(&vll) {
            println!("   best vLLM efficiency:   {}", best.label);
        }
    }
    Json::obj(vec![("figure", Json::str("11")), ("rows", Json::arr(out_rows))])
}

/// Fig. 12: TBT breakdown vs batch size at fixed context (pipelining off).
pub fn fig12() -> Json {
    println!("Fig. 12: token-generation latency breakdown (rotational pipelining disabled)");
    println!(
        "{:<12} {:>6} {:>6} {:>11} {:>11} {:>11} {:>11}",
        "model", "seq", "batch", "model", "attention", "network", "TBT"
    );
    let mut rows = Vec::new();
    for (model, dop) in [(&LLAMA_65B, (2usize, 4usize)), (&LLAMA3_70B, (2, 4))] {
        for &l in &[4096usize, 8192] {
            for &b in &[8usize, 32, 64, 128, 256] {
                let cfg = LaminaConfig {
                    concurrent_batches: 1,
                    ..LaminaConfig::standard(model, &H100, &H20, dop, &FHBN)
                };
                // skip batches whose KV cannot fit
                if b * l > cfg.kv_capacity_tokens() {
                    continue;
                }
                let c = wave_cost(&cfg, b, b * l);
                println!(
                    "{:<12} {:>6} {:>6} {:>11} {:>11} {:>11} {:>11}",
                    model.name,
                    l,
                    b,
                    crate::util::stats::fmt_duration(c.t_model),
                    crate::util::stats::fmt_duration(c.t_attn),
                    crate::util::stats::fmt_duration(c.t_net_visible),
                    crate::util::stats::fmt_duration(c.tbt)
                );
                rows.push(Json::obj(vec![
                    ("model", Json::str(model.name)),
                    ("seq", Json::num(l as f64)),
                    ("batch", Json::num(b as f64)),
                    ("model_s", Json::num(c.t_model)),
                    ("attn_s", Json::num(c.t_attn)),
                    ("network_s", Json::num(c.t_net_visible)),
                    ("tbt_s", Json::num(c.tbt)),
                ]));
            }
        }
    }
    Json::obj(vec![("figure", Json::str("12")), ("rows", Json::arr(rows))])
}

/// Fig. 14: TBT with overlap enabled vs disabled (pipelining off, ctx 4096).
pub fn fig14() -> Json {
    println!("Fig. 14: resource-utilisation overlapping ablation (ctx 4096)");
    println!(
        "{:<12} {:>6} {:>12} {:>12} {:>9}",
        "model", "batch", "overlap TBT", "seq TBT", "saving"
    );
    let mut rows = Vec::new();
    let cases: [(&'static LlmSpec, (usize, usize)); 2] =
        [(&LLAMA_65B, (2, 2)), (&LLAMA3_70B, (2, 4))];
    for (model, dop) in cases {
        for &b in &[8usize, 16, 32, 64, 128, 256] {
            let base = LaminaConfig {
                concurrent_batches: 1,
                ..LaminaConfig::standard(model, &H100, &H20, dop, &FHBN)
            };
            if b * 4096 > base.kv_capacity_tokens() {
                continue;
            }
            let on = wave_cost(&base, b, b * 4096);
            let off = wave_cost(&LaminaConfig { overlap: false, ..base }, b, b * 4096);
            let saving = 1.0 - on.tbt / off.tbt;
            println!(
                "{:<12} {:>6} {:>12} {:>12} {:>8.1}%",
                model.name,
                b,
                crate::util::stats::fmt_duration(on.tbt),
                crate::util::stats::fmt_duration(off.tbt),
                saving * 100.0
            );
            rows.push(Json::obj(vec![
                ("model", Json::str(model.name)),
                ("batch", Json::num(b as f64)),
                ("overlap_tbt", Json::num(on.tbt)),
                ("sequential_tbt", Json::num(off.tbt)),
                ("saving", Json::num(saving)),
            ]));
        }
    }
    Json::obj(vec![("figure", Json::str("14")), ("rows", Json::arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_well_formed() {
        assert_eq!(table3().get("rows").as_arr().unwrap().len(), 3);
        assert_eq!(table5().get("rows").as_arr().unwrap().len(), 3);
        let t4 = table4(4000, 7);
        assert_eq!(t4.get("rows").as_arr().unwrap().len(), 4);
    }

    #[test]
    fn fig10_lamina_wins_everywhere() {
        // Full-size runs (2000+ requests, `lamina fig10`) land at ≈ +1.5 %
        // to +54 % gain and 2.2× batch (paper: 16.1–90.1 %, 2.39×). The test
        // uses a smaller trace sample, so allow a small negative floor for
        // the shortest loaded windows.
        let f = fig10(1000, 11);
        let min_gain = f.get("min_gain").as_f64().unwrap();
        let max_gain = f.get("max_gain").as_f64().unwrap();
        assert!(min_gain > -0.05, "Lamina should at least match vLLM (min gain {min_gain})");
        assert!(max_gain > 0.15, "headline gains should appear ({max_gain})");
        assert!(max_gain < 3.0, "gain should stay in a plausible band ({max_gain})");
        let ratio = f.get("mean_batch_ratio").as_f64().unwrap();
        assert!(ratio > 1.5, "batch ratio {ratio}");
    }

    #[test]
    fn fig12_model_time_flat_attention_grows() {
        let f = fig12();
        let rows = f.get("rows").as_arr().unwrap();
        let m65_4k: Vec<&Json> = rows
            .iter()
            .filter(|r| {
                r.get("model").as_str() == Some("LLaMA-65B")
                    && r.get("seq").as_usize() == Some(4096)
            })
            .collect();
        assert!(m65_4k.len() >= 2);
        let first = m65_4k.first().unwrap();
        let last = m65_4k.last().unwrap();
        // model time ~flat (bandwidth-bound), attention grows ~linearly
        let mgrow = last.get("model_s").as_f64().unwrap() / first.get("model_s").as_f64().unwrap();
        let agrow = last.get("attn_s").as_f64().unwrap() / first.get("attn_s").as_f64().unwrap();
        assert!(mgrow < 1.5, "model grew {mgrow}");
        assert!(agrow > 3.0, "attention grew only {agrow}");
    }

    #[test]
    fn fig14_savings_band() {
        let f = fig14();
        let rows = f.get("rows").as_arr().unwrap();
        let max_65 = rows
            .iter()
            .filter(|r| r.get("model").as_str() == Some("LLaMA-65B"))
            .map(|r| r.get("saving").as_f64().unwrap())
            .fold(0.0, f64::max);
        let max_70 = rows
            .iter()
            .filter(|r| r.get("model").as_str() == Some("LLaMA3-70B"))
            .map(|r| r.get("saving").as_f64().unwrap())
            .fold(0.0, f64::max);
        // paper: up to 13.2 % (65B) and 3.5 % (70B); G=1 saves more than G=8
        assert!(max_65 > 0.02 && max_65 < 0.30, "65B saving {max_65}");
        assert!(max_70 < max_65, "GQA should shrink the overlap headroom");
    }
}

/// SLO-attainment sweep (extension): open-loop Poisson arrivals at rising
/// offered load, reporting sustained throughput, queue wait and TBT-SLO
/// attainment — the quantitative form of the paper's "latency is still
/// within the SLO of online interactive LLM services".
pub fn slo_sweep(n_requests: usize, seed: u64) -> Json {
    use crate::coordinator::openloop::{run_open_loop, Engine2};
    let slo = 0.2; // 200 ms per token, interactive bound
    println!("SLO sweep: LLaMA3-70B, Azure-Conv arrivals, TBT SLO {} ms", slo * 1e3);
    println!(
        "{:<8} {:>9} {:>12} {:>11} {:>12} {:>12} {:>8}",
        "engine", "load rps", "tok/s", "mean TBT", "p99 TBT", "queue wait", "SLO"
    );
    let reqs = synthesize(&crate::trace::AZURE_CONV, n_requests, seed);
    let lam = LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN);
    let vll = VllmConfig::standard(&LLAMA3_70B, &H100, 4);
    let mut rows = Vec::new();
    for rps in [2.0, 8.0, 20.0, 40.0, 80.0] {
        for (name, engine) in [("Lamina", Engine2::Lamina(&lam)), ("vLLM", Engine2::Vllm(&vll))] {
            let r = run_open_loop(&engine, &reqs, rps, slo, seed);
            println!(
                "{:<8} {:>9.1} {:>12.0} {:>11} {:>12} {:>12} {:>7.1}%",
                name,
                rps,
                r.tokens_per_s,
                crate::util::stats::fmt_duration(r.mean_tbt_s),
                crate::util::stats::fmt_duration(r.p99_tbt_s),
                crate::util::stats::fmt_duration(r.mean_queue_wait_s),
                r.slo_attainment * 100.0
            );
            rows.push(Json::obj(vec![
                ("engine", Json::str(name)),
                ("rps", Json::num(rps)),
                ("tokens_per_s", Json::num(r.tokens_per_s)),
                ("mean_tbt", Json::num(r.mean_tbt_s)),
                ("p99_tbt", Json::num(r.p99_tbt_s)),
                ("queue_wait", Json::num(r.mean_queue_wait_s)),
                ("slo_attainment", Json::num(r.slo_attainment)),
            ]));
        }
    }
    Json::obj(vec![("experiment", Json::str("slo-sweep")), ("rows", Json::arr(rows))])
}

#[cfg(test)]
mod slo_tests {
    use super::*;

    #[test]
    fn slo_sweep_runs_and_orders() {
        let j = slo_sweep(300, 5);
        let rows = j.get("rows").as_arr().unwrap();
        assert_eq!(rows.len(), 10);
        // at every load both engines keep the 200 ms TBT SLO (the paper's
        // claim) for this GQA model
        for r in rows {
            assert!(r.get("slo_attainment").as_f64().unwrap() > 0.9,
                "{:?}", r.get("engine"));
        }
    }
}
