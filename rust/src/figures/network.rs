//! Fig. 13: the network ping-pong microbenchmark, plus a live round-trip
//! over the in-process transport to validate the data path.

use crate::netsim::pingpong::{default_sizes, sweep};
use crate::netsim::stack::{ALL_STACKS, FHBN, LINE_RATE_400G, NCCL};
use crate::netsim::transport::link;
use crate::util::json::Json;
use crate::util::stats::{fmt_bandwidth, fmt_duration};

/// Fig. 13: RTT and effective bandwidth per stack per message size.
pub fn fig13() -> Json {
    println!("Fig. 13: GPU-GPU ping-pong over 400 Gbps RoCE (modelled)");
    println!("{:<11} {:>12} {:>12} {:>14}", "stack", "bytes", "RTT", "bandwidth");
    let sizes = default_sizes();
    let pts = sweep(&sizes, LINE_RATE_400G);
    let mut rows = Vec::new();
    for p in &pts {
        println!(
            "{:<11} {:>12.0} {:>12} {:>14}",
            p.stack,
            p.bytes,
            fmt_duration(p.rtt_s),
            fmt_bandwidth(p.bw_bytes_per_s)
        );
        rows.push(Json::obj(vec![
            ("stack", Json::str(p.stack)),
            ("bytes", Json::num(p.bytes)),
            ("rtt_s", Json::num(p.rtt_s)),
            ("bw", Json::num(p.bw_bytes_per_s)),
        ]));
    }
    let small_fhbn = FHBN.rtt(8.0, LINE_RATE_400G);
    let small_nccl = NCCL.rtt(8.0, LINE_RATE_400G);
    println!(
        "=> small-msg RTT: FHBN {} vs NCCL {} ({:.1}% reduction; paper: 33.0 µs vs 66.6 µs, 50.5%)",
        fmt_duration(small_fhbn),
        fmt_duration(small_nccl),
        (1.0 - small_fhbn / small_nccl) * 100.0
    );
    println!(
        "=> peak bandwidth: FHBN {} ({:.1}% of line) vs NCCL {} (paper: 45.7 vs 35.5 GB/s)",
        fmt_bandwidth(FHBN.effective_bw(1e9, LINE_RATE_400G)),
        FHBN.effective_bw(1e9, LINE_RATE_400G) / LINE_RATE_400G * 100.0,
        fmt_bandwidth(NCCL.effective_bw(1e9, LINE_RATE_400G)),
    );
    Json::obj(vec![("figure", Json::str("13")), ("rows", Json::arr(rows))])
}

/// Live ping-pong over the in-process transport: actually bounces a buffer
/// between two threads with wall-clock pacing (time_scale=1) and reports the
/// measured RTT alongside the model. Validates the data path end to end.
pub fn live_pingpong(bytes: usize, iters: usize) -> Json {
    println!("live transport ping-pong: {bytes} bytes × {iters} iters per stack");
    let mut rows = Vec::new();
    for stack in ALL_STACKS {
        let (a, b) = link::<Vec<u8>>(stack, LINE_RATE_400G, 1.0);
        let echo = std::thread::spawn(move || {
            while let Ok((buf, n)) = b.recv() {
                if buf.is_empty() {
                    break;
                }
                if b.send(buf, n).is_err() {
                    break;
                }
            }
        });
        let payload = vec![0xabu8; bytes];
        // warmup
        a.send(payload.clone(), bytes).unwrap();
        a.recv().unwrap();
        let t0 = std::time::Instant::now();
        for _ in 0..iters {
            a.send(payload.clone(), bytes).unwrap();
            let (back, _) = a.recv().unwrap();
            assert_eq!(back.len(), bytes);
        }
        let rtt = t0.elapsed().as_secs_f64() / iters as f64;
        a.send(Vec::new(), 0).unwrap(); // stop echo thread
        echo.join().unwrap();
        let model = stack.rtt(bytes as f64, LINE_RATE_400G);
        println!(
            "{:<11} measured {:>12}  model {:>12}",
            stack.name,
            fmt_duration(rtt),
            fmt_duration(model)
        );
        rows.push(Json::obj(vec![
            ("stack", Json::str(stack.name)),
            ("bytes", Json::num(bytes as f64)),
            ("measured_rtt_s", Json::num(rtt)),
            ("model_rtt_s", Json::num(model)),
        ]));
    }
    Json::obj(vec![("live_pingpong", Json::Bool(true)), ("rows", Json::arr(rows))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig13_rows_cover_stacks() {
        let f = fig13();
        let rows = f.get("rows").as_arr().unwrap();
        assert_eq!(rows.len() % ALL_STACKS.len(), 0);
        // FHBN strictly fastest at every size
        let n = rows.len() / ALL_STACKS.len();
        for i in 0..n {
            let fhbn = rows[i].get("rtt_s").as_f64().unwrap();
            for s in 1..ALL_STACKS.len() {
                let other = rows[s * n + i].get("rtt_s").as_f64().unwrap();
                assert!(fhbn <= other);
            }
        }
    }

    #[test]
    fn live_pingpong_matches_model() {
        let j = live_pingpong(64, 20);
        for r in j.get("rows").as_arr().unwrap() {
            let meas = r.get("measured_rtt_s").as_f64().unwrap();
            let model = r.get("model_rtt_s").as_f64().unwrap();
            // sleep-based pacing can only overshoot; allow generous slack
            assert!(meas >= model * 0.9, "{meas} < {model}");
            assert!(meas < model * 40.0 + 2e-3, "{meas} ≫ {model}");
        }
    }
}
