//! Analysis figures & tables (paper §2–3): Table 1, Fig. 2, Fig. 3, Fig. 4.
//! Each harness prints the paper's series and returns a JSON record.

use crate::devices::roofline::{atime, min_interconnect_bw, mtime, mtime_roofline};
use crate::devices::specs::{ALL_DEVICES, H100, H20, LLAMA3_70B};
use crate::util::json::Json;

/// Table 1: device specifications.
pub fn table1() -> Json {
    println!("Table 1: accelerator specifications");
    println!(
        "{:<8} {:>12} {:>10} {:>12} {:>8} {:>10} {:>10} {:>10}",
        "device", "BF16 TFLOPs", "mem GiB", "bw TB/s", "W", "ICI GB/s", "net Gbps", "$/hr"
    );
    let mut rows = Vec::new();
    for d in ALL_DEVICES {
        println!(
            "{:<8} {:>12.0} {:>10.0} {:>12.2} {:>8.0} {:>10.0} {:>10.0} {:>10.2}",
            d.name, d.bf16_tflops, d.mem_gib, d.mem_bw_tbs, d.power_w, d.ici_gbs,
            d.net_gbps, d.price_hr
        );
        rows.push(Json::obj(vec![
            ("device", Json::str(d.name)),
            ("bf16_tflops", Json::num(d.bf16_tflops)),
            ("mem_gib", Json::num(d.mem_gib)),
            ("mem_bw_tbs", Json::num(d.mem_bw_tbs)),
            ("power_w", Json::num(d.power_w)),
            ("price_hr", Json::num(d.price_hr)),
        ]));
    }
    Json::obj(vec![("table", Json::str("1")), ("rows", Json::arr(rows))])
}

/// Fig. 2: non-attention latency + MFU vs batch, H100, TP ∈ {2,4,8}, with
/// roofline projections (LLaMA3-70B).
pub fn fig2() -> Json {
    let model = &LLAMA3_70B;
    let batches: Vec<usize> = log_batches(1, 1024);
    println!("Fig. 2: non-attention operators, {} on H100", model.name);
    println!(
        "{:>6} {:>4} {:>12} {:>12} {:>8} {:>8}",
        "batch", "TP", "latency", "roofline", "MFU", "MBU"
    );
    let mut rows = Vec::new();
    for &tp in &[2usize, 4, 8] {
        for &b in &batches {
            let c = mtime(model, &H100, b, tp);
            let proj = mtime_roofline(model, &H100, b, tp);
            println!(
                "{:>6} {:>4} {:>12} {:>12} {:>7.1}% {:>7.1}%",
                b,
                tp,
                crate::util::stats::fmt_duration(c.time_s),
                crate::util::stats::fmt_duration(proj),
                c.mfu * 100.0,
                c.mbu * 100.0
            );
            rows.push(Json::obj(vec![
                ("batch", Json::num(b as f64)),
                ("tp", Json::num(tp as f64)),
                ("latency_s", Json::num(c.time_s)),
                ("roofline_s", Json::num(proj)),
                ("mfu", Json::num(c.mfu)),
                ("mbu", Json::num(c.mbu)),
            ]));
        }
    }
    Json::obj(vec![("figure", Json::str("2")), ("rows", Json::arr(rows))])
}

/// Fig. 3: attention latency + MBU vs batch for seq ∈ {2k, 8k, 32k} on
/// H100 and H20 (LLaMA3-70B).
pub fn fig3() -> Json {
    let model = &LLAMA3_70B;
    let batches = log_batches(1, 512);
    println!("Fig. 3: attention operator, {}", model.name);
    println!(
        "{:>7} {:>6} {:>7} {:>12} {:>8} {:>8}",
        "device", "batch", "seq", "latency", "MBU", "MFU"
    );
    let mut rows = Vec::new();
    for dev in [&H100, &H20] {
        for &l in &[2048usize, 8192, 32768] {
            for &b in &batches {
                let c = atime(model, dev, b, l, 1);
                println!(
                    "{:>7} {:>6} {:>7} {:>12} {:>7.1}% {:>7.1}%",
                    dev.name,
                    b,
                    l,
                    crate::util::stats::fmt_duration(c.time_s),
                    c.mbu * 100.0,
                    c.mfu * 100.0
                );
                rows.push(Json::obj(vec![
                    ("device", Json::str(dev.name)),
                    ("batch", Json::num(b as f64)),
                    ("seq", Json::num(l as f64)),
                    ("latency_s", Json::num(c.time_s)),
                    ("mbu", Json::num(c.mbu)),
                    ("mfu", Json::num(c.mfu)),
                ]));
            }
        }
    }
    Json::obj(vec![("figure", Json::str("3")), ("rows", Json::arr(rows))])
}

/// Fig. 4: minimum interconnect bandwidth vs batch size, α = 0.2,
/// LLaMA3-70B split between one H100 (model) and one H20 (attention).
pub fn fig4(alpha: f64) -> Json {
    let model = &LLAMA3_70B;
    println!("Fig. 4: required network bandwidth (α = {alpha})");
    println!("{:>6} {:>7} {:>14}", "batch", "seq", "min bandwidth");
    let mut rows = Vec::new();
    for &l in &[2048usize, 4096, 8192] {
        for b in [1usize, 10, 25, 50, 100, 150, 200, 250, 300] {
            let bw = min_interconnect_bw(model, &H100, &H20, b, l, alpha, (1, 1));
            println!(
                "{:>6} {:>7} {:>14}",
                b,
                l,
                crate::util::stats::fmt_bandwidth(bw)
            );
            rows.push(Json::obj(vec![
                ("batch", Json::num(b as f64)),
                ("seq", Json::num(l as f64)),
                ("min_bw_bytes_s", Json::num(bw)),
            ]));
        }
    }
    Json::obj(vec![
        ("figure", Json::str("4")),
        ("alpha", Json::num(alpha)),
        ("rows", Json::arr(rows)),
    ])
}

fn log_batches(lo: usize, hi: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut b = lo;
    while b <= hi {
        v.push(b);
        b *= 2;
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_all_devices() {
        let t = table1();
        assert_eq!(t.get("rows").as_arr().unwrap().len(), ALL_DEVICES.len());
    }

    #[test]
    fn fig2_shape_claims() {
        let f = fig2();
        let rows = f.get("rows").as_arr().unwrap();
        // small-batch rows have MFU < 20 %
        for r in rows {
            let b = r.get("batch").as_usize().unwrap();
            let mfu = r.get("mfu").as_f64().unwrap();
            if b <= 64 {
                assert!(mfu < 0.20, "B={b} mfu={mfu}");
            }
        }
        // latency within ~2× of roofline everywhere (overheads only)
        for r in rows {
            let t = r.get("latency_s").as_f64().unwrap();
            let p = r.get("roofline_s").as_f64().unwrap();
            assert!(t >= p * 0.99 && t < p * 2.5);
        }
    }

    #[test]
    fn fig3_mbu_above_70_for_b20_plus() {
        let f = fig3();
        for r in f.get("rows").as_arr().unwrap() {
            if r.get("batch").as_usize().unwrap() >= 16 {
                assert!(r.get("mbu").as_f64().unwrap() > 0.70);
            }
        }
    }

    #[test]
    fn fig4_manageable_bandwidth() {
        // Paper's claim: < 30 GB/s for the evaluated (≥ 4k) contexts, and
        // always within a 400 Gbps NIC's 45.7 GB/s achievable rate.
        let f = fig4(0.2);
        for r in f.get("rows").as_arr().unwrap() {
            let bw = r.get("min_bw_bytes_s").as_f64().unwrap();
            assert!(bw < 45e9, "bw={bw}");
            if r.get("seq").as_usize().unwrap() >= 4096 {
                assert!(bw < 30e9, "bw={bw}");
            }
        }
    }
}
