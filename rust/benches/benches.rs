//! Lamina bench suite (`cargo bench`) — custom harness (no criterion in the
//! offline toolchain; see `util::bench`).
//!
//! Covers the serving hot paths (L3), the PJRT execution path (runtime),
//! and one end-to-end bench per paper experiment family:
//!   * decode-step benches     → Figs. 10/12 (real tiny-model TBT)
//!   * attention-exec benches  → Fig. 3 (kernel-side cost vs batch/seq)
//!   * overlap on/off bench    → Fig. 14
//!   * transport benches       → Fig. 13
//!   * simulator benches       → Figs. 10–12 regeneration cost
//!   * coordinator micro       → batcher/KV/min-cut/pipeline hot paths
//!
//! Env: LAMINA_BENCH_QUICK=1 shrinks budgets (CI smoke).

use lamina::baseline::vllm::{run_vllm, VllmConfig};
use lamina::coordinator::batcher::ContinuousBatcher;
use lamina::coordinator::sim::{run_lamina, wave_cost, LaminaConfig};
use lamina::devices::specs::{H100, H20, LLAMA3_70B};
use lamina::kvcache::{BlockAllocator, KvRegistry};
use lamina::netsim::stack::{FHBN, LINE_RATE_400G};
use lamina::netsim::transport::link;
use lamina::opgraph::builder::{build_decode_graph, llama3_70b_shape, tiny_shape};
use lamina::opgraph::schedule::emit_programs;
use lamina::opgraph::slicer::split_at_attention;
use lamina::runtime::engine::Engine;
use lamina::runtime::host::HostTensor;
use lamina::trace::{fixed_length, synthesize, AZURE_CONV};
use lamina::util::bench::{black_box, Bench};
use lamina::util::json::Json;
use lamina::workers::{DisaggPipeline, PipelineOpts};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let mut b = Bench::new();

    bench_coordinator(&mut b);
    bench_opgraph(&mut b);
    bench_transport(&mut b);
    bench_simulators(&mut b);
    if artifacts_dir().join("manifest.json").exists() {
        bench_runtime(&mut b);
        bench_pipeline(&mut b);
    } else {
        eprintln!("NOTE: artifacts/ missing — skipping PJRT benches (run `make artifacts`)");
    }

    print!("{}", b.summary());
}

// ---- L3 coordinator micro-benches ---------------------------------------

fn bench_coordinator(b: &mut Bench) {
    // continuous batcher: admission + step over a realistic backlog
    let reqs = synthesize(&AZURE_CONV, 4096, 1);
    b.run("batcher/admit+step (4k backlog)", || {
        let mut batcher = ContinuousBatcher::new(500_000, 256);
        batcher.submit_all(reqs.iter().copied());
        batcher.admit();
        for _ in 0..8 {
            black_box(batcher.step());
            batcher.admit();
        }
    });

    // KV block allocator hot path
    b.run("kvcache/alloc+release (256 blocks)", || {
        let mut a = BlockAllocator::new(4096, 16);
        let blocks = a.alloc_n(256).unwrap();
        a.release_all(&blocks);
        black_box(a.free_blocks());
    });

    b.run("kvcache/registry admit-append-evict", || {
        let mut r = KvRegistry::new(8192, 16);
        for id in 0..64 {
            r.admit(id, 100).unwrap();
        }
        for id in 0..64 {
            for _ in 0..4 {
                r.append(id).unwrap();
            }
        }
        for id in 0..64 {
            r.evict(id);
        }
        black_box(r.live_requests());
    });

    // per-iteration cost-model evaluation (the sim's inner loop)
    let cfg = LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN);
    b.run("sim/wave_cost (70B, B=256)", || {
        black_box(wave_cost(&cfg, 256, 256 * 4096));
    });
}

// ---- model-converter benches ---------------------------------------------

fn bench_opgraph(b: &mut Bench) {
    b.run("opgraph/build tiny graph", || {
        black_box(build_decode_graph(tiny_shape()));
    });
    b.run("opgraph/split 80-layer graph (min-cut ×80)", || {
        let dg = build_decode_graph(llama3_70b_shape());
        black_box(split_at_attention(&dg));
    });
    let dg = build_decode_graph(llama3_70b_shape());
    let sr = split_at_attention(&dg);
    b.run("opgraph/emit 81 slice programs", || {
        black_box(emit_programs(&dg, &sr));
    });
}

// ---- network transport ----------------------------------------------------

fn bench_transport(b: &mut Bench) {
    let (a, z) = link::<Vec<u8>>(&FHBN, LINE_RATE_400G, 0.0);
    let payload = vec![0u8; 4096];
    b.run("transport/send+recv 4 KiB (unpaced)", || {
        a.send(payload.clone(), 4096).unwrap();
        black_box(z.recv().unwrap());
    });

    b.run("netsim/pingpong sweep (Fig. 13 data)", || {
        let sizes = lamina::netsim::pingpong::default_sizes();
        black_box(lamina::netsim::pingpong::sweep(&sizes, LINE_RATE_400G));
    });
}

// ---- paper-scale simulators (one per serving figure) ----------------------

fn bench_simulators(b: &mut Bench) {
    let reqs = fixed_length(128, 2048, 4);
    let lam = LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN);
    b.run("sim/fig10 lamina run (128 reqs)", || {
        black_box(run_lamina(&lam, &reqs));
    });
    let vll = VllmConfig::standard(&LLAMA3_70B, &H100, 4);
    b.run("sim/fig10 vllm run (128 reqs)", || {
        black_box(run_vllm(&vll, &reqs));
    });
}

// ---- PJRT runtime (real artifacts) ----------------------------------------

fn bench_runtime(b: &mut Bench) {
    let engine = Engine::load(artifacts_dir()).expect("engine");
    engine.warmup().expect("warmup");
    let mc = engine.manifest.config.clone();
    let hd = mc.head_dim;

    // slice_mid at batch buckets (the model worker's dominant call)
    for &bucket in &[1usize, 8] {
        let attn_out = HostTensor::zeros_f32(vec![bucket, mc.heads, hd]);
        let resid = HostTensor::zeros_f32(vec![bucket, mc.d]);
        let pos = HostTensor::i32(vec![bucket], vec![0; bucket]);
        let weights: Vec<String> = [
            "layer0.wo", "layer0.ffn_norm", "layer0.w_gate", "layer0.w_up",
            "layer0.w_down", "layer1.attn_norm", "layer1.wq", "layer1.wk",
            "layer1.wv",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        b.run(&format!("pjrt/slice_mid b{bucket}"), || {
            black_box(
                engine
                    .execute("slice_mid", bucket, None, &[&attn_out, &resid, &pos], &weights)
                    .unwrap(),
            );
        });
    }

    // attention at batch × seq buckets (the attention worker's call)
    for &(bucket, seq) in &[(1usize, 64usize), (8, 64), (8, 256)] {
        let q = HostTensor::zeros_f32(vec![bucket, mc.heads, hd]);
        let kc = HostTensor::zeros_f32(vec![bucket, mc.kv_heads, seq, hd]);
        let vc = HostTensor::zeros_f32(vec![bucket, mc.kv_heads, seq, hd]);
        let lens = HostTensor::i32(vec![bucket], vec![seq as i32 / 2; bucket]);
        b.run(&format!("pjrt/attention b{bucket} s{seq}"), || {
            black_box(
                engine
                    .execute_raw("attention", bucket, Some(seq), &[&q, &kc, &vc, &lens])
                    .unwrap(),
            );
        });
    }
}

// ---- end-to-end decode steps (Figs. 10/12/14 on the real stack) -----------

fn bench_pipeline(b: &mut Bench) {
    for (label, overlap) in [("overlap", true), ("sequential", false)] {
        let pipe = DisaggPipeline::start(PipelineOpts {
            overlap,
            ..PipelineOpts::new(artifacts_dir())
        })
        .expect("pipeline");
        // warm every bucket once
        pipe.decode(&[vec![1, 2, 3]], 2).unwrap();
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![1 + i, 2, 3]).collect();
        pipe.decode(&prompts, 2).unwrap();
        b.run(&format!("e2e/decode-step b4 ({label})"), || {
            black_box(pipe.decode(&prompts, 1).unwrap());
        });
        pipe.shutdown();
    }

    // JSON substrate on a real manifest (startup path)
    let text = std::fs::read_to_string(artifacts_dir().join("manifest.json")).unwrap();
    b.run("json/parse manifest", || {
        black_box(Json::parse(&text).unwrap());
    });
}
