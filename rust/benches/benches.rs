//! Lamina bench suite (`cargo bench`) — custom harness (no criterion in the
//! offline toolchain; see `util::bench`).
//!
//! Covers the serving hot paths (L3), the PJRT execution path (runtime),
//! and one end-to-end bench per paper experiment family:
//!   * decode-step benches     → Figs. 10/12 (real tiny-model TBT)
//!   * attention-exec benches  → Fig. 3 (kernel-side cost vs batch/seq)
//!   * overlap on/off bench    → Fig. 14
//!   * transport benches       → Fig. 13
//!   * net codec + TCP benches → frame encode/decode GB/s, loopback RTT
//!   * simulator benches       → Figs. 10–12 regeneration cost
//!   * coordinator micro       → batcher/KV/min-cut/pipeline hot paths
//!   * paged-KV hot loop       → gather/append vs a dense reference cache
//!     (with and without gather-scratch reuse), plus zero-copy staging vs
//!     legacy deep-copy staging
//!   * native-kernel benches   → block-table-native decode attention (zero
//!     copied KV bytes) vs gather + reference — at every KV storage dtype
//!     (`kv=f32|f16|int8` rows; the quantized rows assert the ≥1.8×/≥3×
//!     per-step bytes-read reduction) — plus the unrolled-vs-naive inner
//!     loop delta and the e2e decode step on both attention backends
//!     (needs artifacts)
//!   * serving-engine benches  → `e2e/continuous-batching` vs the legacy
//!     wave driver on a mixed-length trace (tokens/s; asserts the
//!     step-driven scheduler is no slower — needs artifacts)
//!   * obs overhead benches    → disabled-span guard, counter/histogram
//!     hot path, enabled-span record cost, and the decode-step raw-vs-
//!     instrumented pair (asserts ≤2% tracing-off overhead in-binary)
//!
//! Env: LAMINA_BENCH_QUICK=1 shrinks budgets (CI smoke).
//!
//! Machine-readable output: the decode-path benches land in
//! `rust/BENCH_decode.json` (name, mean+min ns/iter, host bytes copied per
//! iter, KV bytes read per iter, KV blocks in use) so perf trajectory can
//! be tracked across PRs; `scripts/bench_guard.py` gates decode-path rows
//! on **min** ns/iter and on any growth in copied or read bytes.

use lamina::baseline::vllm::{run_vllm, VllmConfig};
use lamina::coordinator::batcher::ContinuousBatcher;
use lamina::coordinator::sim::{run_lamina, wave_cost, LaminaConfig};
use lamina::devices::specs::{H100, H20, LLAMA3_70B};
use lamina::kernels::{axpy, dot, paged_attn, reference, AttnBackendKind, Par};
use lamina::kvcache::{ArenaCfg, BlockAllocator, KvDtype, KvRegistry, PagedKvArena};
use lamina::net::{codec, tcp, Transport};
use lamina::netsim::stack::{FHBN, LINE_RATE_400G};
use lamina::netsim::transport::link;
use lamina::kvcache::quant::{f16_bits_to_f32, f16_bits_widen, f32_to_f16_bits};
use lamina::opgraph::builder::{build_decode_graph, llama3_70b_shape, tiny_shape};
use lamina::opgraph::schedule::emit_programs;
use lamina::opgraph::slicer::split_at_attention;
use lamina::runtime::engine::Engine;
use lamina::scheduler::{
    AdmissionKind as SchedAdmission, GroupMode, KvBudget, KvOccupancy, RequestState, SchedCfg,
    Scheduler,
};
use lamina::runtime::host::{copies, kv_reads, HostTensor};
use lamina::trace::{fixed_length, synthesize, Request, AZURE_CONV};
use lamina::util::bench::{black_box, Bench};
use lamina::util::json::Json;
use lamina::workers::{DisaggPipeline, PipelineOpts, WireMsg};

fn artifacts_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Host bytes physically copied by one invocation of `f`.
fn copied_bytes(mut f: impl FnMut()) -> u64 {
    copies::reset();
    f();
    copies::total()
}

/// KV-arena bytes read (native-kernel working set) by one invocation.
fn kv_read_bytes(mut f: impl FnMut()) -> u64 {
    kv_reads::reset();
    f();
    kv_reads::total()
}

/// One `BENCH_decode.json` row. `ns` is (mean, min) per iteration — the
/// regression guard gates decode-path rows on **min** (the jitter-robust
/// statistic; mean is kept for human trend-reading).
fn row(name: &str, ns: (f64, f64), copy_bytes: u64, kv_blocks: usize) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ns_per_iter", Json::num(ns.0)),
        ("ns_per_iter_min", Json::num(ns.1)),
        ("host_copy_bytes_per_iter", Json::num(copy_bytes as f64)),
        ("kv_blocks_in_use", Json::num(kv_blocks as f64)),
    ])
}

/// A decode-step row: like [`row`] plus the derived tokens/s (the paper's
/// headline unit for the attention hot loop) and the per-step KV bytes
/// **read** by the kernel (the bandwidth term quantized storage shrinks).
fn row_step(
    name: &str,
    ns: (f64, f64),
    copy_bytes: u64,
    read_bytes: u64,
    kv_blocks: usize,
    tokens_per_iter: usize,
) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ns_per_iter", Json::num(ns.0)),
        ("ns_per_iter_min", Json::num(ns.1)),
        ("host_copy_bytes_per_iter", Json::num(copy_bytes as f64)),
        ("kv_read_bytes_per_iter", Json::num(read_bytes as f64)),
        ("kv_blocks_in_use", Json::num(kv_blocks as f64)),
        (
            "tokens_per_s",
            Json::num(tokens_per_iter as f64 / (ns.0.max(1.0) * 1e-9)),
        ),
    ])
}

/// Mean/min ns-per-iter pair of a bench result.
fn ns_of(r: &lamina::util::bench::BenchResult) -> (f64, f64) {
    (r.mean_s * 1e9, r.min_s * 1e9)
}

/// A net-path row: wire bytes moved per iteration + derived GB/s.
fn row_net(name: &str, ns: (f64, f64), wire_bytes: usize) -> Json {
    Json::obj(vec![
        ("name", Json::str(name)),
        ("ns_per_iter", Json::num(ns.0)),
        ("ns_per_iter_min", Json::num(ns.1)),
        ("wire_bytes_per_iter", Json::num(wire_bytes as f64)),
        ("gb_per_s", Json::num(wire_bytes as f64 / ns.0.max(1.0))),
    ])
}

fn main() {
    let mut b = Bench::new();
    let mut rows: Vec<Json> = Vec::new();

    bench_coordinator(&mut b);
    bench_scheduler(&mut b, &mut rows);
    bench_opgraph(&mut b);
    bench_transport(&mut b);
    bench_net(&mut b, &mut rows);
    bench_net_batch(&mut b, &mut rows);
    bench_net_mux(&mut b, &mut rows);
    bench_simulators(&mut b);
    let gather_ratio = bench_kv_paged(&mut b, &mut rows);
    bench_kernels(&mut b, &mut rows);
    bench_host_staging(&mut b, &mut rows);
    bench_obs(&mut b, &mut rows);
    bench_failover(&mut b, &mut rows);
    bench_degrade(&mut b, &mut rows);
    if artifacts_dir().join("manifest.json").exists() {
        bench_runtime(&mut b);
        bench_pipeline(&mut b, &mut rows);
    } else {
        eprintln!("NOTE: artifacts/ missing — skipping PJRT benches (run `make artifacts`)");
    }

    print!("{}", b.summary());

    let doc = Json::obj(vec![
        ("bench", Json::str("decode")),
        ("quick", Json::Bool(b.is_quick())),
        ("gather_copy_ratio_dense_over_paged", Json::num(gather_ratio)),
        ("rows", Json::arr(rows)),
    ]);
    let out_path =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("BENCH_decode.json");
    std::fs::write(&out_path, doc.pretty()).expect("write BENCH_decode.json");
    eprintln!("wrote {}", out_path.display());
}

// ---- L3 coordinator micro-benches ---------------------------------------

fn bench_coordinator(b: &mut Bench) {
    // continuous batcher: admission + step over a realistic backlog
    let reqs = synthesize(&AZURE_CONV, 4096, 1);
    b.run("batcher/admit+step (4k backlog)", || {
        let mut batcher = ContinuousBatcher::new(500_000, 256);
        batcher.submit_all(reqs.iter().copied());
        batcher.admit();
        for _ in 0..8 {
            black_box(batcher.step());
            batcher.admit();
        }
    });

    // KV block allocator hot path
    b.run("kvcache/alloc+release (256 blocks)", || {
        let mut a = BlockAllocator::new(4096, 16);
        let blocks = a.alloc_n(256).unwrap();
        a.release_all(&blocks);
        black_box(a.free_blocks());
    });

    b.run("kvcache/registry admit-append-evict", || {
        let mut r = KvRegistry::new(8192, 16);
        for id in 0..64 {
            r.admit(id, 100).unwrap();
        }
        for id in 0..64 {
            for _ in 0..4 {
                r.append(id).unwrap();
            }
        }
        for id in 0..64 {
            r.evict(id);
        }
        black_box(r.live_requests());
    });

    // per-iteration cost-model evaluation (the sim's inner loop)
    let cfg = LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN);
    b.run("sim/wave_cost (70B, B=256)", || {
        black_box(wave_cost(&cfg, 256, 256 * 4096));
    });
}

// ---- request-lifecycle scheduler overhead ---------------------------------

/// Scheduler bookkeeping cost under sustained submit churn (ISSUE 6
/// satellite): 10k requests flow through submit → admit → prefill/decode
/// notes → retire against a mock model, with a rolling backlog so the
/// waiting queue, slot pool and reservations all stay hot. The overcommit
/// row additionally runs the per-step pressure valve (block-granular
/// reservation growth + preempt-and-requeue) under a budget tight enough
/// to keep evicting — the worst-case control-plane overhead of ISSUE 6.
fn bench_scheduler(b: &mut Bench, rows: &mut Vec<Json>) {
    const N: usize = 10_000;
    let cfg = |overcommit: bool, budget: KvBudget| SchedCfg {
        max_context: 64,
        total_slots: 64,
        group_slots: 64,
        grouping: GroupMode::Packed,
        use_prefill: true,
        kv_block_size: 4,
        block_bytes: 256,
        budget,
        overcommit,
    };
    for (name, oc, budget) in [
        ("sched/submit+drain 10k churn", false, KvBudget::Blocks(256)),
        ("sched/submit+drain 10k churn overcommit+preempt", true, KvBudget::Blocks(48)),
    ] {
        let mut preempted = 0u64;
        let ns = ns_of(b.run(name, || {
            let mut s = Scheduler::new(cfg(oc, budget), SchedAdmission::Fifo.build());
            let mut submitted = 0usize;
            let mut done = 0usize;
            while done < N {
                // rolling backlog: keep ~512 requests in flight or queued
                while submitted < N && submitted < done + 512 {
                    s.submit(vec![7; 1 + submitted % 8], 1 + submitted % 4).unwrap();
                    submitted += 1;
                }
                let occ = KvOccupancy {
                    blocks_in_use: s.reserved_blocks(),
                    bytes_in_use: s.reserved_bytes(),
                };
                if oc {
                    s.pressure_preempt(occ);
                }
                s.admit(occ);
                if let Some(p) = s.next_prefill() {
                    let c = s.prompt_chunk(p.id, 8);
                    s.note_prefill_chunk(p.id, c.len(), 1);
                } else {
                    for plan in s.decode_plan() {
                        for r in &plan {
                            s.note_decode(r.id, 1);
                        }
                    }
                }
                done += s.take_retirements().len();
            }
            preempted = s.preempted_total();
            black_box(done);
        }));
        if oc {
            assert!(preempted > 0, "tight-budget churn must exercise preemption");
        }
        rows.push(row(name, ns, 0, 0));
    }
}

// ---- model-converter benches ---------------------------------------------

fn bench_opgraph(b: &mut Bench) {
    b.run("opgraph/build tiny graph", || {
        black_box(build_decode_graph(tiny_shape()));
    });
    b.run("opgraph/split 80-layer graph (min-cut ×80)", || {
        let dg = build_decode_graph(llama3_70b_shape());
        black_box(split_at_attention(&dg));
    });
    let dg = build_decode_graph(llama3_70b_shape());
    let sr = split_at_attention(&dg);
    b.run("opgraph/emit 81 slice programs", || {
        black_box(emit_programs(&dg, &sr));
    });
}

// ---- network transport ----------------------------------------------------

fn bench_transport(b: &mut Bench) {
    let (a, z) = link::<Vec<u8>>(&FHBN, LINE_RATE_400G, 0.0);
    let payload = vec![0u8; 4096];
    b.run("transport/send+recv 4 KiB (unpaced)", || {
        a.send(payload.clone(), 4096).unwrap();
        black_box(z.recv().unwrap());
    });

    b.run("netsim/pingpong sweep (Fig. 13 data)", || {
        let sizes = lamina::netsim::pingpong::default_sizes();
        black_box(lamina::netsim::pingpong::sweep(&sizes, LINE_RATE_400G));
    });
}

// ---- net: frame codec + real-socket loopback ------------------------------

/// Codec encode/decode throughput on decode-sized payloads and TCP-loopback
/// round-trips over serialized frames. All rows land in `BENCH_decode.json`
/// with `wire_bytes_per_iter`/`gb_per_s` so codec and socket-path perf is
/// tracked across PRs alongside the decode benches.
fn bench_net(b: &mut Bench, rows: &mut Vec<Json>) {
    // StepKv with 2 × [32, 8, 64] f32 tensors (128 KiB of payload), the
    // shape class the per-layer decode wire carries
    let t = HostTensor::f32(
        vec![32, 8, 64],
        (0..32 * 8 * 64).map(|i| (i % 251) as f32 * 0.5).collect(),
    );
    let msg = WireMsg::StepKv { layer: 0, k: t.clone(), v: t.clone() };
    let mut frame = Vec::new();
    let frame_len = codec::encode(&msg, &mut frame);

    let mut scratch: Vec<u8> = Vec::with_capacity(frame_len);
    let enc_ns = ns_of(b.run("net/codec encode StepKv 128KiB", || {
        scratch.clear();
        black_box(codec::encode(&msg, &mut scratch));
    }));
    rows.push(row_net("net/codec encode StepKv 128KiB", enc_ns, frame_len));

    let dec_ns = ns_of(b.run("net/codec decode StepKv 128KiB", || {
        black_box(codec::decode_frame(&frame).unwrap().unwrap());
    }));
    rows.push(row_net("net/codec decode StepKv 128KiB", dec_ns, frame_len));

    // the element-wise conversion the bulk-cast ENCODE fast path replaced,
    // kept as the baseline so BENCH_decode.json shows the GB/s delta
    // (payload-only: the same 2 × 64 KiB of f32s the StepKv frame carries;
    // the decode baseline shares the codec's single-pass collect and mostly
    // isolates the frame/checksum overhead of the full decode row)
    let payload_bytes = 2 * t.byte_size();
    let mut base_buf: Vec<u8> = Vec::with_capacity(payload_bytes);
    let base_enc_ns = ns_of(b.run(
        "net/codec encode StepKv 128KiB (element-wise baseline)",
        || {
            base_buf.clear();
            codec::put_f32_le_elementwise(&mut base_buf, t.as_f32());
            codec::put_f32_le_elementwise(&mut base_buf, t.as_f32());
            black_box(base_buf.len());
        },
    ));
    rows.push(row_net(
        "net/codec encode StepKv 128KiB (element-wise baseline)",
        base_enc_ns,
        payload_bytes,
    ));

    let raw: Vec<u8> = base_buf.clone();
    let base_dec_ns = ns_of(b.run(
        "net/codec decode StepKv 128KiB (element-wise baseline)",
        || {
            black_box(codec::get_f32_le_elementwise(&raw));
        },
    ));
    rows.push(row_net(
        "net/codec decode StepKv 128KiB (element-wise baseline)",
        base_dec_ns,
        payload_bytes,
    ));
    eprintln!(
        "net/codec fast-path speedup: encode {:.2}×, decode {:.2}× vs element-wise",
        base_enc_ns.0 / enc_ns.0.max(1.0),
        base_dec_ns.0 / dec_ns.0.max(1.0)
    );

    // TCP loopback round-trip through real kernel sockets (serialized both
    // ways; the echo peer is a thread, as the attention workers are)
    let (leader, worker) = tcp::pair().expect("tcp loopback pair");
    let echo = std::thread::spawn(move || loop {
        match worker.recv() {
            Ok(WireMsg::Shutdown) | Err(_) => return,
            Ok(m) => {
                if worker.send(m).is_err() {
                    return;
                }
            }
        }
    });

    let ctl = WireMsg::Retire { slot: 3 };
    let ctl_bytes = codec::encoded_len(&ctl);
    let ctl_ns = ns_of(b.run("net/tcp loopback rtt control (16 B)", || {
        leader.send(ctl.clone()).unwrap();
        black_box(leader.recv().unwrap());
    }));
    rows.push(row_net("net/tcp loopback rtt control (16 B)", ctl_ns, 2 * ctl_bytes));

    let out = WireMsg::AttnOut {
        layer: 0,
        out: HostTensor::f32(vec![8, 8, 64], vec![0.25; 8 * 8 * 64]),
    };
    let out_bytes = codec::encoded_len(&out);
    let out_ns = ns_of(b.run("net/tcp loopback rtt AttnOut (16 KiB)", || {
        leader.send(out.clone()).unwrap();
        black_box(leader.recv().unwrap());
    }));
    rows.push(row_net("net/tcp loopback rtt AttnOut (16 KiB)", out_ns, 2 * out_bytes));

    leader.send(WireMsg::Shutdown).unwrap();
    echo.join().unwrap();
}

// ---- net: per-step frame batching (one writev per worker per step) --------

/// The tentpole wire win: a decode step's per-layer message burst rides
/// ONE batch envelope flushed with ONE vectored write, vs one `write`
/// syscall per frame. The syscall ratio is measured in-binary from the
/// `net.writev_calls` counter and must be ≥4× (the acceptance bar); the
/// two rows track the wall-clock side in BENCH_decode.json.
fn bench_net_batch(b: &mut Bench, rows: &mut Vec<Json>) {
    let (leader, worker) = tcp::pair().expect("tcp loopback pair");
    // sink thread: drain everything so socket buffers never stall a send
    let sink = std::thread::spawn(move || loop {
        match worker.recv() {
            Ok(WireMsg::Shutdown) | Err(_) => return,
            Ok(_) => {}
        }
    });

    // a decode step's burst on the chaos geometry: 2 layers × (StepQ +
    // StepKv) × 2 shard messages collapsed onto one link — 8 frames
    let q = HostTensor::f32(vec![4, 4, 16], (0..4 * 4 * 16).map(|i| i as f32 * 0.25).collect());
    let kv = HostTensor::f32(vec![4, 2, 16], (0..4 * 2 * 16).map(|i| i as f32 * 0.5).collect());
    let mut burst: Vec<WireMsg> = Vec::new();
    for layer in 0..2usize {
        for _shard in 0..2usize {
            burst.push(WireMsg::StepQ {
                layer,
                slots: vec![0, 1, 2, 3],
                q: q.clone(),
                lens: vec![3, 3, 3, 3],
                seq_bucket: 64,
                overlap: false,
            });
            burst.push(WireMsg::StepKv { layer, k: kv.clone(), v: kv.clone() });
        }
    }
    let wire_bytes: usize = burst.iter().map(codec::encoded_len).sum();

    // baseline: one write syscall per frame (the pre-batching send path)
    let per_ns = ns_of(b.run("net/frame-batch per-message (8-frame burst)", || {
        for m in &burst {
            leader.send(m.clone()).unwrap();
        }
    }));
    rows.push(row_net("net/frame-batch per-message (8-frame burst)", per_ns, wire_bytes));

    // batched: the whole burst buffered, then ONE envelope flush
    let batch_ns = ns_of(b.run("net/frame-batch batched writev (8-frame burst)", || {
        for m in &burst {
            leader.send_buffered(m.clone()).unwrap();
        }
        leader.flush().unwrap();
    }));
    rows.push(row_net("net/frame-batch batched writev (8-frame burst)", batch_ns, wire_bytes));

    // measured syscall ratio: writev calls per batched burst, counted by
    // the transport itself (a partial write may take >1, so measure)
    let wv = lamina::obs::registry().counter("net.writev_calls");
    let wv0 = wv.get();
    let reps = 64u64;
    for _ in 0..reps {
        for m in &burst {
            leader.send_buffered(m.clone()).unwrap();
        }
        leader.flush().unwrap();
    }
    let writev_per_burst = (wv.get() - wv0) as f64 / reps as f64;
    let ratio = burst.len() as f64 / writev_per_burst.max(1.0);
    assert!(
        ratio >= 4.0,
        "frame batching must cut write syscalls ≥4× per step burst \
         ({} frames over {writev_per_burst:.2} writev calls = {ratio:.1}×)",
        burst.len()
    );
    eprintln!(
        "net/frame-batch: {} frames/burst in {writev_per_burst:.2} writev calls ({ratio:.1}× \
         fewer write syscalls), per-message {:.0} ns vs batched {:.0} ns",
        burst.len(),
        per_ns.0,
        batch_ns.0
    );

    leader.send(WireMsg::Shutdown).unwrap();
    sink.join().unwrap();
}

// ---- net: multiplexed gather vs sequential send→recv ----------------------

/// The leader I/O-loop win: with W workers each taking ~service_us to
/// reply, the old sequential send→recv ladder pays W × service while the
/// batched-send + `poll(2)` readiness loop overlaps all W services and
/// pays ~max(service). Both rows land in BENCH_decode.json under the
/// bench-guard `net/mux-step` prefix.
fn bench_net_mux(b: &mut Bench, rows: &mut Vec<Json>) {
    use lamina::net::mux;
    use std::time::Duration;

    if !mux::supported() {
        eprintln!("NOTE: poll(2) mux unsupported on this platform — skipping net/mux-step");
        return;
    }
    const W: usize = 4;
    const SERVICE_US: u64 = 150;

    let mut links = Vec::new();
    let mut echoes = Vec::new();
    for _ in 0..W {
        let (l, w) = tcp::pair().expect("tcp loopback pair");
        echoes.push(std::thread::spawn(move || loop {
            match w.recv() {
                Ok(WireMsg::Shutdown) | Err(_) => return,
                Ok(m) => {
                    // stand-in for the worker's attention compute
                    std::thread::sleep(Duration::from_micros(SERVICE_US));
                    if w.send(m).is_err() {
                        return;
                    }
                }
            }
        }));
        links.push(l);
    }
    let ping = WireMsg::Retire { slot: 1 };
    let wire_bytes = 2 * W * codec::encoded_len(&ping);

    // sequential ladder: send worker i, block on its reply, move on
    let seq_ns = ns_of(b.run("net/mux-step sequential send→recv (4 workers)", || {
        for l in &links {
            l.send(ping.clone()).unwrap();
            loop {
                if let Some(m) = l.recv_timeout(Duration::from_secs(1)).unwrap() {
                    black_box(m);
                    break;
                }
            }
        }
    }));
    rows.push(row_net("net/mux-step sequential send→recv (4 workers)", seq_ns, wire_bytes));

    // mux loop: batched send to all, then poll-driven gather
    let mux_ns = ns_of(b.run("net/mux-step batched send + poll gather (4 workers)", || {
        for l in &links {
            l.send_buffered(ping.clone()).unwrap();
        }
        for l in &links {
            l.flush().unwrap();
        }
        let mut outstanding: Vec<usize> = (0..links.len()).collect();
        while !outstanding.is_empty() {
            // free sweep: frames already decoded or buffered in userspace
            // are invisible to poll
            outstanding
                .retain(|&i| !matches!(links[i].recv_timeout(Duration::ZERO), Ok(Some(_))));
            if outstanding.is_empty() {
                break;
            }
            let fds: Vec<i32> =
                outstanding.iter().map(|&i| links[i].poll_fd().expect("tcp fd")).collect();
            let ready = mux::wait_readable(&fds, Duration::from_millis(100)).expect("poll");
            let ready_wi: Vec<usize> = ready.iter().map(|&ri| outstanding[ri]).collect();
            for wi in ready_wi {
                if let Ok(Some(m)) = links[wi].recv_timeout(Duration::from_millis(1)) {
                    black_box(m);
                    outstanding.retain(|&o| o != wi);
                }
            }
        }
    }));
    rows.push(row_net("net/mux-step batched send + poll gather (4 workers)", mux_ns, wire_bytes));
    eprintln!(
        "net/mux-step: sequential {:.0} µs vs mux {:.0} µs over {W} workers at ~{SERVICE_US} µs \
         service ({:.2}× wall-clock)",
        seq_ns.0 / 1e3,
        mux_ns.0 / 1e3,
        seq_ns.0 / mux_ns.0.max(1.0)
    );

    for l in &links {
        l.send(WireMsg::Shutdown).unwrap();
    }
    for e in echoes {
        e.join().unwrap();
    }
}

// ---- paper-scale simulators (one per serving figure) ----------------------

fn bench_simulators(b: &mut Bench) {
    let reqs = fixed_length(128, 2048, 4);
    let lam = LaminaConfig::standard(&LLAMA3_70B, &H100, &H20, (2, 4), &FHBN);
    b.run("sim/fig10 lamina run (128 reqs)", || {
        black_box(run_lamina(&lam, &reqs));
    });
    let vll = VllmConfig::standard(&LLAMA3_70B, &H100, 4);
    b.run("sim/fig10 vllm run (128 reqs)", || {
        black_box(run_vllm(&vll, &reqs));
    });
}

// ---- paged KV hot loop (tentpole benches, artifact-free) -------------------

/// Dense per-slot reference shard (the seed's layout): `[KH_s, max_seq, hd]`
/// per slot, gathered with full-`seq_bucket` copies every step regardless
/// of live context. Kept here as the comparator the paged arena replaced.
struct DenseShard {
    k: Vec<f32>,
    v: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn dense_gather(
    shards: &[DenseShard],
    slots: &[u32],
    khs: usize,
    max_seq: usize,
    hd: usize,
    bucket: usize,
    seq_bucket: usize,
) -> (HostTensor, HostTensor) {
    let row = khs * seq_bucket * hd;
    let mut k = vec![0.0f32; bucket * row];
    let mut v = vec![0.0f32; bucket * row];
    let mut copied = 0usize;
    for (b, &slot) in slots.iter().enumerate() {
        let cache = &shards[slot as usize];
        for h in 0..khs {
            let src = h * max_seq * hd;
            let dst = b * row + h * seq_bucket * hd;
            let n = seq_bucket * hd;
            k[dst..dst + n].copy_from_slice(&cache.k[src..src + n]);
            v[dst..dst + n].copy_from_slice(&cache.v[src..src + n]);
            copied += 2 * n;
        }
    }
    copies::add(copied * 4);
    let shape = vec![bucket, khs, seq_bucket, hd];
    (HostTensor::f32(shape.clone(), k), HostTensor::f32(shape, v))
}

/// Benches the paged arena's gather/append against the dense reference and
/// returns the measured dense/paged copy-bytes ratio for the JSON header.
fn bench_kv_paged(b: &mut Bench, rows: &mut Vec<Json>) -> f64 {
    const LAYERS: usize = 1;
    const KHS: usize = 2;
    const HD: usize = 64;
    const BS: usize = 16;
    const SLOTS: usize = 8;
    const LEN: usize = 100; // live context per slot (steady-state decode)
    const SEQ: usize = 256; // seq bucket the kernel runs at
    const MAX_SEQ: usize = 512;

    // paged arena seeded with LEN tokens per slot
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: LAYERS,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: SLOTS,
        block_size: BS,
        initial_blocks: SLOTS,
        dtype: KvDtype::F32,
    });
    let slot_ids: Vec<u32> = (0..SLOTS as u32).collect();
    let step = HostTensor::f32(
        vec![SLOTS, KHS, HD],
        (0..SLOTS * KHS * HD).map(|i| i as f32).collect(),
    );
    for t in 0..LEN {
        let lens = vec![t as i32; SLOTS];
        arena.append_step(&slot_ids, 0, &step, &step, &lens);
    }

    // dense reference seeded identically
    let mut shards: Vec<DenseShard> = (0..SLOTS)
        .map(|_| DenseShard { k: vec![0.0; KHS * MAX_SEQ * HD], v: vec![0.0; KHS * MAX_SEQ * HD] })
        .collect();
    let sd = step.as_f32();
    for (s, shard) in shards.iter_mut().enumerate() {
        for t in 0..LEN {
            for h in 0..KHS {
                let dst = h * MAX_SEQ * HD + t * HD;
                let src = (s * KHS + h) * HD;
                shard.k[dst..dst + HD].copy_from_slice(&sd[src..src + HD]);
                shard.v[dst..dst + HD].copy_from_slice(&sd[src..src + HD]);
            }
        }
    }

    let kv_blocks = arena.stats().blocks_in_use;

    let paged_ns = ns_of(b.run(&format!("kv/gather paged b{SLOTS} s{SEQ} (len {LEN})"), || {
        black_box(arena.gather(&slot_ids, 0, SLOTS, SEQ));
    }));
    let paged_bytes = copied_bytes(|| {
        black_box(arena.gather(&slot_ids, 0, SLOTS, SEQ));
    });
    rows.push(row(
        &format!("kv/gather paged b{SLOTS} s{SEQ} (len {LEN})"),
        paged_ns,
        paged_bytes,
        kv_blocks,
    ));

    // same gather with scratch reuse disabled: measures the per-step
    // [bucket, KH_s, seq, hd] allocation cost the reuse removes
    arena.set_scratch_reuse(false);
    let fresh_ns = ns_of(b.run(&format!("kv/gather paged b{SLOTS} s{SEQ} (no scratch reuse)"), || {
        black_box(arena.gather(&slot_ids, 0, SLOTS, SEQ));
    }));
    rows.push(row(
        &format!("kv/gather paged b{SLOTS} s{SEQ} (no scratch reuse)"),
        fresh_ns,
        paged_bytes,
        kv_blocks,
    ));
    arena.set_scratch_reuse(true);

    let dense_ns = ns_of(b.run(&format!("kv/gather dense b{SLOTS} s{SEQ} (len {LEN})"), || {
        black_box(dense_gather(&shards, &slot_ids, KHS, MAX_SEQ, HD, SLOTS, SEQ));
    }));
    let dense_bytes = copied_bytes(|| {
        black_box(dense_gather(&shards, &slot_ids, KHS, MAX_SEQ, HD, SLOTS, SEQ));
    });
    rows.push(row(
        &format!("kv/gather dense b{SLOTS} s{SEQ} (len {LEN})"),
        dense_ns,
        dense_bytes,
        SLOTS * MAX_SEQ / BS, // dense residency in block-equivalents
    ));

    // decode-append + retire lifecycle (allocator + zeroing + writes),
    // at every storage dtype (quantized appends pay convert/requant cost
    // on the write path; the rows keep that honest)
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let name = format!("kv/append 32 tokens + retire (paged, kv={})", dtype.name());
        let cycle_ns = ns_of(b.run(&name, || {
            let mut a = PagedKvArena::new(ArenaCfg {
                layers: LAYERS,
                kv_heads: KHS,
                head_dim: HD,
                max_seq: MAX_SEQ,
                slots: 1,
                block_size: BS,
                initial_blocks: 2,
                dtype,
            });
            let one = step.take_batch(1);
            for t in 0..32 {
                a.append_step(&[0], 0, &one, &one, &[t]);
            }
            a.retire(0);
            black_box(a.stats().blocks_in_use);
        }));
        rows.push(row(&name, cycle_ns, 0, 0));
    }

    let ratio = dense_bytes as f64 / paged_bytes.max(1) as f64;
    eprintln!(
        "kv/gather host-copy bytes: dense {dense_bytes} vs paged {paged_bytes} \
         ({ratio:.2}× fewer with paging at len {LEN}/{SEQ})"
    );
    ratio
}

// ---- native block-table kernel vs gather + reference (artifact-free) ------

/// The tentpole comparison: one decode-step attention pass with the
/// block-table-native kernel (reads the arena in place — **zero** host
/// copies) vs the gather-then-compute shape of the engine path (the
/// per-step `[bucket, KH_s, seq, hd]` staging copy + a two-pass reference
/// kernel standing in for the artifact). `host_copy_bytes_per_iter` is the
/// proof: the native row must stay at 0 while the gather row charges the
/// full staged K/V every step.
fn bench_kernels(b: &mut Bench, rows: &mut Vec<Json>) {
    const KHS: usize = 2;
    const G: usize = 4;
    const HS: usize = KHS * G;
    const HD: usize = 64;
    const BS: usize = 16;
    const SLOTS: usize = 8;
    const LEN: usize = 100; // live context per slot (steady-state decode)
    const SEQ: usize = 256; // seq bucket the engine kernel would run at
    const MAX_SEQ: usize = 512;

    let slot_ids: Vec<u32> = (0..SLOTS as u32).collect();
    let step = HostTensor::f32(
        vec![SLOTS, KHS, HD],
        (0..SLOTS * KHS * HD).map(|i| ((i % 97) as f32) * 0.02 - 1.0).collect(),
    );
    let q = HostTensor::f32(
        vec![SLOTS, HS, HD],
        (0..SLOTS * HS * HD).map(|i| ((i % 89) as f32) * 0.025 - 1.1).collect(),
    );
    let lens = vec![LEN as i32; SLOTS];

    // one arena per storage dtype, identical append streams: the kv=f16 /
    // kv=int8 rows must show ≥1.8× / ≥3× fewer KV bytes read per step than
    // kv=f32, all at ZERO copied bytes (the ISSUE 4 acceptance criterion,
    // asserted right here so the bench run machine-checks it)
    let mut read_by_dtype = Vec::new();
    for dtype in [KvDtype::F32, KvDtype::F16, KvDtype::Int8] {
        let mut arena = PagedKvArena::new(ArenaCfg {
            layers: 1,
            kv_heads: KHS,
            head_dim: HD,
            max_seq: MAX_SEQ,
            slots: SLOTS,
            block_size: BS,
            initial_blocks: SLOTS,
            dtype,
        });
        for t in 0..LEN {
            let step_lens = vec![t as i32; SLOTS];
            arena.append_step(&slot_ids, 0, &step, &step, &step_lens);
        }
        let kv_blocks = arena.stats().blocks_in_use;

        let name =
            format!("kernel/decode-step paged-native kv={} b{SLOTS} s{SEQ} (len {LEN})", dtype.name());
        let native_ns = ns_of(b.run(&name, || {
            black_box(paged_attn(&arena, &slot_ids, 0, &q, &lens, SEQ, Par::Threads(4)));
        }));
        let native_bytes = copied_bytes(|| {
            black_box(paged_attn(&arena, &slot_ids, 0, &q, &lens, SEQ, Par::Threads(4)));
        });
        let native_reads = kv_read_bytes(|| {
            black_box(paged_attn(&arena, &slot_ids, 0, &q, &lens, SEQ, Par::Threads(4)));
        });
        assert_eq!(native_bytes, 0, "native kernel must not copy KV (kv={})", dtype.name());
        assert!(native_reads > 0, "native kernel must charge its KV reads");
        rows.push(row_step(&name, native_ns, native_bytes, native_reads, kv_blocks, SLOTS));
        read_by_dtype.push((dtype, native_reads, native_ns.0));

        if dtype == KvDtype::F32 {
            // the engine-shaped comparator only needs the f32 arena
            let name = format!("kernel/decode-step gather+ref b{SLOTS} s{SEQ} (len {LEN})");
            let gather_ns = ns_of(b.run(&name, || {
                let (kc, vc) = arena.gather(&slot_ids, 0, SLOTS, SEQ);
                black_box(reference::decode_attention_ref(&q, &kc, &vc, &lens));
            }));
            let gather_bytes = copied_bytes(|| {
                let (kc, vc) = arena.gather(&slot_ids, 0, SLOTS, SEQ);
                black_box(reference::decode_attention_ref(&q, &kc, &vc, &lens));
            });
            assert!(gather_bytes > 0, "gather path must charge its staging copy");
            rows.push(row_step(&name, gather_ns, gather_bytes, 0, kv_blocks, SLOTS));

            // satellite: single-thread decode step, unrolled mul_add inner
            // loops (the delta row vs the naive baseline below)
            let name = format!("kernel/decode-step paged-native t1 b{SLOTS} s{SEQ} (len {LEN})");
            let t1_ns = ns_of(b.run(&name, || {
                black_box(paged_attn(&arena, &slot_ids, 0, &q, &lens, SEQ, Par::Threads(1)));
            }));
            rows.push(row_step(&name, t1_ns, 0, native_reads, kv_blocks, SLOTS));
        }
    }
    let f32_reads = read_by_dtype[0].1 as f64;
    for &(dtype, reads, ns) in &read_by_dtype[1..] {
        let cut = f32_reads / reads.max(1) as f64;
        let need = match dtype {
            KvDtype::F16 => 1.8,
            _ => 3.0,
        };
        assert!(
            cut >= need,
            "kv={} must cut per-step KV bytes read ≥{need}× vs f32 (got {cut:.2}×)",
            dtype.name()
        );
        eprintln!(
            "kernel/decode-step kv={}: {reads} B read/step ({cut:.2}× fewer than f32), {ns:.0} ns",
            dtype.name()
        );
    }

    // satellite: the scalar inner loops themselves — 4-lane mul_add unroll
    // vs the naive sequential loop it replaced, single-threaded, on a
    // decode-shaped workload (seq × hd dots + axpys)
    let seq_w = 2048usize;
    let kbuf: Vec<f32> = (0..seq_w * HD).map(|i| ((i % 101) as f32) * 0.019 - 0.95).collect();
    let vbuf: Vec<f32> = (0..seq_w * HD).map(|i| ((i % 103) as f32) * 0.018 - 0.9).collect();
    let qv: Vec<f32> = (0..HD).map(|i| (i as f32) * 0.013 - 0.4).collect();
    let mut acc = vec![0.0f32; HD];

    let unrolled = ns_of(b.run("kernel/inner-loop dot+axpy 4-lane mul_add t1", || {
        acc.fill(0.0);
        for t in 0..seq_w {
            let s = dot(&qv, &kbuf[t * HD..][..HD]);
            axpy(&mut acc, s * 1e-4, &vbuf[t * HD..][..HD]);
        }
        black_box(acc[0]);
    }));
    rows.push(row("kernel/inner-loop dot+axpy 4-lane mul_add t1", unrolled, 0, 0));

    let naive = ns_of(b.run("kernel/inner-loop dot+axpy naive t1", || {
        acc.fill(0.0);
        for t in 0..seq_w {
            let kr = &kbuf[t * HD..][..HD];
            let mut s = 0.0f32;
            for (x, y) in qv.iter().zip(kr) {
                s += x * y;
            }
            let e = s * 1e-4;
            for (a, y) in acc.iter_mut().zip(&vbuf[t * HD..][..HD]) {
                *a += e * y;
            }
        }
        black_box(acc[0]);
    }));
    rows.push(row("kernel/inner-loop dot+axpy naive t1", naive, 0, 0));
    eprintln!(
        "kernel/inner-loop: unrolled mul_add {:.0} ns vs naive {:.0} ns ({:.2}× single-thread)",
        unrolled.0,
        naive.0,
        naive.0 / unrolled.0.max(1.0)
    );

    // satellite: bulk f16→f32 widen (the engine backend's staging decode
    // of f16 block storage) — the 16-lane chunked integer path vs the
    // element-wise branchy convert it replaced, on a gather-sized buffer
    let n = 1 << 16;
    let src: Vec<u16> =
        (0..n).map(|i| f32_to_f16_bits(((i % 509) as f32) * 0.013 - 3.0)).collect();
    let mut dst = vec![0.0f32; n];
    let bulk = ns_of(b.run("kernel/f16_widen bulk 64k (16-lane chunks)", || {
        f16_bits_widen(&src, &mut dst);
        black_box(dst[0]);
    }));
    rows.push(row("kernel/f16_widen bulk 64k (16-lane chunks)", bulk, 0, 0));
    let elem = ns_of(b.run("kernel/f16_widen element-wise 64k", || {
        for (d, &h) in dst.iter_mut().zip(&src) {
            *d = f16_bits_to_f32(h);
        }
        black_box(dst[0]);
    }));
    rows.push(row("kernel/f16_widen element-wise 64k", elem, 0, 0));
    // the fast path must agree bit-for-bit with the reference convert
    let mut widened = vec![0.0f32; n];
    f16_bits_widen(&src, &mut widened);
    let per_elem: Vec<f32> = src.iter().map(|&h| f16_bits_to_f32(h)).collect();
    assert_eq!(widened, per_elem, "bulk f16 widen diverged from element-wise");
    eprintln!(
        "kernel/f16_widen: bulk {:.0} ns vs element-wise {:.0} ns ({:.2}× on 64k lanes)",
        bulk.0,
        elem.0,
        elem.0 / bulk.0.max(1.0)
    );
}

// ---- zero-copy staging vs legacy deep-copy staging ------------------------

fn bench_host_staging(b: &mut Bench, rows: &mut Vec<Json>) {
    let t = HostTensor::f32(
        vec![8, 4, 64],
        (0..8 * 4 * 64).map(|i| i as f32 * 0.5).collect(),
    );

    // the seed's take_batch deep-copied; it is now an Arc view
    let view_ns = ns_of(b.run("host/take_batch b8→b4 (arc view)", || {
        black_box(t.take_batch(4));
    }));
    let view_bytes = copied_bytes(|| {
        black_box(t.take_batch(4));
    });
    rows.push(row("host/take_batch b8→b4 (arc view)", view_ns, view_bytes, 0));

    // legacy behavior, preserved here as the comparator
    let legacy_ns = ns_of(b.run("host/take_batch b8→b4 (legacy deep copy)", || {
        let row_elems = 4 * 64;
        let d = t.as_f32()[..4 * row_elems].to_vec();
        copies::add(d.len() * 4);
        black_box(HostTensor::f32(vec![4, 4, 64], d));
    }));
    let legacy_bytes = copied_bytes(|| {
        let row_elems = 4 * 64;
        let d = t.as_f32()[..4 * row_elems].to_vec();
        copies::add(d.len() * 4);
        black_box(HostTensor::f32(vec![4, 4, 64], d));
    });
    rows.push(row(
        "host/take_batch b8→b4 (legacy deep copy)",
        legacy_ns,
        legacy_bytes,
        0,
    ));
}

// ---- obs overhead benches --------------------------------------------------
//
// The observability layer's contract is near-zero cost when disabled: a
// span call is one relaxed load, a counter add one relaxed fetch_add. The
// rows below pin those numbers in BENCH_decode.json (guarded by
// bench_guard.py under the obs/ prefix), and the decode-step pair asserts
// IN-BINARY that the instrumented kernel entry stays within 2% of the raw
// kernel with tracing off — the ISSUE acceptance bound.

fn bench_obs(b: &mut Bench, rows: &mut Vec<Json>) {
    use lamina::kernels::AttnBackend;
    use lamina::obs::{self, trace};
    use lamina::util::threadpool::ScopedPool;

    assert!(!trace::enabled(), "obs benches must start with tracing off");

    // disabled span: what every instrumented call site pays in a normal
    // (untraced) serve
    let disabled = ns_of(b.run("obs/span disabled (guard)", || {
        drop(black_box(obs::span("leader", "bench-disabled")));
    }));
    rows.push(row("obs/span disabled (guard)", disabled, 0, 0));

    // registry hot path: cached handle, relaxed atomics
    let c = obs::registry().counter("bench.obs.counter");
    let counter_ns = ns_of(b.run("obs/counter add", || {
        c.add(1);
    }));
    rows.push(row("obs/counter add", counter_ns, 0, 0));

    let hist = obs::registry().histogram("bench.obs.histo");
    let mut x = 0x9e3779b97f4a7c15u64;
    let histo_ns = ns_of(b.run("obs/histogram record", || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        hist.record(x >> 32);
    }));
    rows.push(row("obs/histogram record", histo_ns, 0, 0));
    c.reset();
    hist.reset();

    // enabled span, measured in drained batches: Bench::run would fill the
    // bounded sink and measure drop-counting instead of recording, so each
    // batch gets a fresh start()/stop() cycle around BATCH span drops
    const BATCH: usize = 4096;
    let batches = if b.is_quick() { 8 } else { 48 };
    let mut sum_ns = 0.0f64;
    let mut min_ns = f64::INFINITY;
    for _ in 0..batches {
        trace::start();
        let t0 = std::time::Instant::now();
        for i in 0..BATCH {
            drop(black_box(obs::span("leader", "bench-enabled").arg("i", i as i64)));
        }
        let per = t0.elapsed().as_secs_f64() * 1e9 / BATCH as f64;
        let events = trace::stop();
        assert_eq!(events.len(), BATCH, "every enabled span must record");
        sum_ns += per;
        min_ns = min_ns.min(per);
    }
    let enabled_ns = (sum_ns / batches as f64, min_ns);
    eprintln!(
        "obs/span enabled: {:.0} ns/span mean, {:.0} ns min ({} batches of {BATCH})",
        enabled_ns.0, enabled_ns.1, batches
    );
    rows.push(row("obs/span enabled (record+drop)", enabled_ns, 0, 0));

    // tracing-disabled overhead on the real decode hot path: the raw
    // kernel call vs NativeBackend::attention (the exact entry the worker
    // loop dispatches through, span guard + shape checks included), same
    // arena, same 4-thread pool size
    const KHS: usize = 2;
    const G: usize = 4;
    const HS: usize = KHS * G;
    const HD: usize = 64;
    const BS: usize = 16;
    const SLOTS: usize = 8;
    const LEN: usize = 100;
    const SEQ: usize = 256;
    const MAX_SEQ: usize = 512;

    let slot_ids: Vec<u32> = (0..SLOTS as u32).collect();
    let step = HostTensor::f32(
        vec![SLOTS, KHS, HD],
        (0..SLOTS * KHS * HD).map(|i| ((i % 97) as f32) * 0.02 - 1.0).collect(),
    );
    let q = HostTensor::f32(
        vec![SLOTS, HS, HD],
        (0..SLOTS * HS * HD).map(|i| ((i % 89) as f32) * 0.025 - 1.1).collect(),
    );
    let lens = vec![LEN as i32; SLOTS];
    let mut arena = PagedKvArena::new(ArenaCfg {
        layers: 1,
        kv_heads: KHS,
        head_dim: HD,
        max_seq: MAX_SEQ,
        slots: SLOTS,
        block_size: BS,
        initial_blocks: SLOTS,
        dtype: KvDtype::F32,
    });
    for t in 0..LEN {
        let step_lens = vec![t as i32; SLOTS];
        arena.append_step(&slot_ids, 0, &step, &step, &step_lens);
    }
    let kv_blocks = arena.stats().blocks_in_use;

    let pool = ScopedPool::new(4);
    let raw = ns_of(b.run("obs/decode-step pre-obs (raw kernel)", || {
        black_box(paged_attn(&arena, &slot_ids, 0, &q, &lens, SEQ, Par::Pool(&pool)));
    }));
    rows.push(row("obs/decode-step pre-obs (raw kernel)", raw, 0, kv_blocks));

    let mut backend = lamina::kernels::NativeBackend::with_threads(4);
    let instr = ns_of(b.run("obs/decode-step instrumented-off", || {
        black_box(
            backend
                .attention(&mut arena, &slot_ids, 0, &q, &lens, SEQ)
                .expect("attention"),
        );
    }));
    rows.push(row("obs/decode-step instrumented-off", instr, 0, kv_blocks));

    // ≤2% on the jitter-robust min statistic, plus an absolute floor so a
    // sub-microsecond-scale wobble on a fast machine can't false-positive
    let bound = raw.1 * 1.02 + 500.0;
    assert!(
        instr.1 <= bound,
        "tracing-disabled instrumentation overhead too high: raw {:.0} ns vs \
         instrumented {:.0} ns (bound {:.0} ns)",
        raw.1,
        instr.1,
        bound
    );
    eprintln!(
        "obs/decode-step overhead (tracing off): raw {:.0} ns → instrumented {:.0} ns \
         ({:+.2}%)",
        raw.1,
        instr.1,
        (instr.1 / raw.1.max(1.0) - 1.0) * 100.0
    );
}

// ---- failover: worker death → recovery cost (artifact-free) ---------------

/// Whole-session chaos benchmark: a scripted multi-request session with a
/// worker link killed mid-decode, auto-recovery on. Each iteration runs
/// detection → preempt-replay-rebuild → drain and must end bit-identical
/// to the fault-free golden pass with zero leaked KV blocks — so the
/// `failover/recovery` row times *verified* recoveries, not just survived
/// ones. Detection latency and tokens replayed come from the session's own
/// `failover.*` registry deltas; `recovered_tokens_per_s` is the headline
/// end-to-end rate (all generated tokens over faulted wall-clock).
fn bench_failover(b: &mut Bench, rows: &mut Vec<Json>) {
    use lamina::net::FaultPlan;
    use lamina::workers::{run_chaos, ChaosCfg};

    // golden pass: the bit-identity reference and the healthy-path cost of
    // the same session with fault injection compiled in but disabled
    let mut cfg = ChaosCfg::default();
    let golden = run_chaos(&cfg).expect("golden chaos session");
    assert_eq!(golden.worker_deaths, 0, "golden run must be fault-free");
    assert_eq!(golden.leaked_blocks, 0);
    let session_tokens: usize = golden.outputs.iter().map(Vec::len).sum();

    // hand-measured whole-session iterations (each spawns worker threads
    // and a replacement; Bench::run's calibration loop would over-sample)
    let iters = if b.is_quick() { 3 } else { 12 };

    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let r = run_chaos(&cfg).expect("healthy chaos session");
        assert_eq!(r.outputs, golden.outputs);
    }
    let healthy_ns = t0.elapsed().as_secs_f64() * 1e9 / iters as f64;

    // kill worker 1's link mid-decode (per link: ~6 prefill sends, then 4
    // per decode iteration — send #20 lands in decode iteration ~4 of 7)
    cfg.fault_plan = Some(FaultPlan::parse("worker=1,kill-send=20").expect("fault plan"));
    let det = lamina::obs::registry().histogram("failover.detection_ns");
    let det0 = det.snapshot();
    let mut sum_ns = 0.0f64;
    let mut min_ns = f64::INFINITY;
    let mut deaths = 0u64;
    let mut replayed = 0u64;
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        let r = run_chaos(&cfg).expect("killed session must auto-recover");
        let per = t0.elapsed().as_secs_f64() * 1e9;
        assert_eq!(r.outputs, golden.outputs, "recovered output must be bit-identical");
        assert_eq!(r.leaked_blocks, 0, "recovery leaked KV blocks");
        assert!(r.worker_deaths >= 1 && r.recoveries >= 1, "kill schedule never fired");
        deaths += r.worker_deaths;
        replayed += r.tokens_replayed;
        sum_ns += per;
        min_ns = min_ns.min(per);
    }
    let faulted = (sum_ns / iters as f64, min_ns);
    let det1 = det.snapshot();
    let detection_ns = if det1.count > det0.count {
        (det1.sum - det0.sum) as f64 / (det1.count - det0.count) as f64
    } else {
        0.0
    };

    eprintln!(
        "failover/recovery: healthy session {:.2} ms → killed+recovered {:.2} ms \
         ({:.1} deaths/iter, {:.1} tokens replayed/iter, detection {:.0} ns)",
        healthy_ns / 1e6,
        faulted.0 / 1e6,
        deaths as f64 / iters as f64,
        replayed as f64 / iters as f64,
        detection_ns
    );

    rows.push(Json::obj(vec![
        ("name", Json::str("failover/recovery")),
        ("ns_per_iter", Json::num(faulted.0)),
        ("ns_per_iter_min", Json::num(faulted.1)),
        ("host_copy_bytes_per_iter", Json::num(0.0)),
        ("healthy_session_ns", Json::num(healthy_ns)),
        ("detection_ns_mean", Json::num(detection_ns)),
        (
            "tokens_replayed_per_iter",
            Json::num(replayed as f64 / iters as f64),
        ),
        (
            "recovered_tokens_per_s",
            Json::num(session_tokens as f64 / (faulted.0.max(1.0) * 1e-9)),
        ),
    ]));
}

// ---- failover: graceful degradation (reshard to the survivors) ------------

/// Degrade-path chaos benchmark: respawn disabled, one worker of a W=4
/// pool killed at a step boundary — the pool reshards live to the three
/// survivors (epoch-fenced W→W−1) and keeps serving. Each iteration must
/// end bit-identical to the fault-free W=4 golden pass with zero leaked
/// KV blocks. The row reports the mean degrade latency from the
/// `failover.reshard_ns` registry (preempt + re-plan + re-welcome +
/// fenced barrier) and the degraded end-to-end token rate against the
/// healthy baseline.
fn bench_degrade(b: &mut Bench, rows: &mut Vec<Json>) {
    use lamina::workers::{run_chaos, ChaosCfg};

    let mut cfg = ChaosCfg::default();
    cfg.workers = 4;
    let golden = run_chaos(&cfg).expect("golden W=4 chaos session");
    assert_eq!(golden.worker_deaths, 0, "golden run must be fault-free");
    assert_eq!(golden.leaked_blocks, 0);
    let session_tokens: usize = golden.outputs.iter().map(Vec::len).sum();

    let iters = if b.is_quick() { 3 } else { 12 };
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let r = run_chaos(&cfg).expect("healthy chaos session");
        assert_eq!(r.outputs, golden.outputs);
    }
    let healthy_ns = t0.elapsed().as_secs_f64() * 1e9 / iters as f64;

    // sever worker 1's link at step boundary 4 (mid-decode) with respawn
    // disabled: every iteration degrades W=4 → 3 exactly once
    cfg.allow_respawn = false;
    cfg.min_workers = 2;
    cfg.kill_at = vec![(4, 1)];
    let reshard = lamina::obs::registry().histogram("failover.reshard_ns");
    let r0 = reshard.snapshot();
    let mut sum_ns = 0.0f64;
    let mut min_ns = f64::INFINITY;
    let mut replayed = 0u64;
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        let r = run_chaos(&cfg).expect("degraded session must keep serving");
        let per = t0.elapsed().as_secs_f64() * 1e9;
        assert_eq!(r.outputs, golden.outputs, "degraded output must be bit-identical");
        assert_eq!(r.leaked_blocks, 0, "degradation leaked KV blocks");
        assert_eq!(r.degrades, 1, "kill schedule never fired");
        assert_eq!(r.final_workers, 3);
        replayed += r.tokens_replayed;
        sum_ns += per;
        min_ns = min_ns.min(per);
    }
    let r1 = reshard.snapshot();
    let reshard_ns = if r1.count > r0.count {
        (r1.sum - r0.sum) as f64 / (r1.count - r0.count) as f64
    } else {
        0.0
    };

    eprintln!(
        "failover/degrade-reshard: healthy W=4 session {:.2} ms → degraded-to-3 {:.2} ms \
         (degrade {:.0} ns, {:.1} tokens replayed/iter)",
        healthy_ns / 1e6,
        sum_ns / iters as f64 / 1e6,
        reshard_ns,
        replayed as f64 / iters as f64
    );

    rows.push(Json::obj(vec![
        ("name", Json::str("failover/degrade-reshard")),
        ("ns_per_iter", Json::num(sum_ns / iters as f64)),
        ("ns_per_iter_min", Json::num(min_ns)),
        ("host_copy_bytes_per_iter", Json::num(0.0)),
        ("healthy_session_ns", Json::num(healthy_ns)),
        ("reshard_ns_mean", Json::num(reshard_ns)),
        (
            "tokens_replayed_per_iter",
            Json::num(replayed as f64 / iters as f64),
        ),
        (
            "degraded_tokens_per_s",
            Json::num(session_tokens as f64 / ((sum_ns / iters as f64).max(1.0) * 1e-9)),
        ),
    ]));
}

// ---- PJRT runtime (real artifacts) ----------------------------------------

fn bench_runtime(b: &mut Bench) {
    let engine = Engine::load(artifacts_dir()).expect("engine");
    engine.warmup().expect("warmup");
    let mc = engine.manifest.config.clone();
    let hd = mc.head_dim;

    // slice_mid at batch buckets (the model worker's dominant call)
    for &bucket in &[1usize, 8] {
        let attn_out = HostTensor::zeros_f32(vec![bucket, mc.heads, hd]);
        let resid = HostTensor::zeros_f32(vec![bucket, mc.d]);
        let pos = HostTensor::i32(vec![bucket], vec![0; bucket]);
        let weights: Vec<String> = [
            "layer0.wo", "layer0.ffn_norm", "layer0.w_gate", "layer0.w_up",
            "layer0.w_down", "layer1.attn_norm", "layer1.wq", "layer1.wk",
            "layer1.wv",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        b.run(&format!("pjrt/slice_mid b{bucket}"), || {
            black_box(
                engine
                    .execute("slice_mid", bucket, None, &[&attn_out, &resid, &pos], &weights)
                    .unwrap(),
            );
        });
    }

    // attention at batch × seq buckets (the attention worker's call)
    for &(bucket, seq) in &[(1usize, 64usize), (8, 64), (8, 256)] {
        let q = HostTensor::zeros_f32(vec![bucket, mc.heads, hd]);
        let kc = HostTensor::zeros_f32(vec![bucket, mc.kv_heads, seq, hd]);
        let vc = HostTensor::zeros_f32(vec![bucket, mc.kv_heads, seq, hd]);
        let lens = HostTensor::i32(vec![bucket], vec![seq as i32 / 2; bucket]);
        b.run(&format!("pjrt/attention b{bucket} s{seq}"), || {
            black_box(
                engine
                    .execute_raw("attention", bucket, Some(seq), &[&q, &kc, &vc, &lens])
                    .unwrap(),
            );
        });
    }
}

// ---- end-to-end decode steps (Figs. 10/12/14 on the real stack) -----------

fn bench_pipeline(b: &mut Bench, rows: &mut Vec<Json>) {
    for (label, overlap) in [("overlap", true), ("sequential", false)] {
        let mut pipe = DisaggPipeline::start(PipelineOpts {
            overlap,
            ..PipelineOpts::new(artifacts_dir())
        })
        .expect("pipeline");
        // warm every bucket once
        pipe.decode(&[vec![1, 2, 3]], 2).unwrap();
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![1 + i, 2, 3]).collect();
        pipe.decode(&prompts, 2).unwrap();
        let name = format!("e2e/decode-step b4 ({label})");
        let ns = ns_of(b.run(&name, || {
            black_box(pipe.decode(&prompts, 1).unwrap());
        }));
        // host bytes copied + KV blocks resident for one full decode pass
        let copy_bytes = copied_bytes(|| {
            black_box(pipe.decode(&prompts, 1).unwrap());
        });
        let kv = pipe.kv_stats().expect("kv stats");
        rows.push(row(&name, ns, copy_bytes, kv.blocks_in_use));
        pipe.shutdown();
    }

    // backend comparison on the single-shard zero-copy wire config: with
    // the native backend the whole decode step performs no host KV copies;
    // the engine backend pays the per-layer gather. tokens/s + copied
    // bytes land in BENCH_decode.json as the tentpole's acceptance rows.
    // The native backend additionally sweeps the KV storage dtype — same
    // protocol, 2×/≈4× fewer KV bytes read per step on the worker.
    for (label, backend, kv_dtype) in [
        ("engine backend", AttnBackendKind::Engine, KvDtype::F32),
        ("native backend", AttnBackendKind::Native, KvDtype::F32),
        ("native backend kv=f16", AttnBackendKind::Native, KvDtype::F16),
        ("native backend kv=int8", AttnBackendKind::Native, KvDtype::Int8),
    ] {
        let mut pipe = DisaggPipeline::start(PipelineOpts {
            attn_workers: 1,
            attn_backend: backend,
            kv_dtype,
            ..PipelineOpts::new(artifacts_dir())
        })
        .expect("pipeline");
        pipe.decode(&[vec![1, 2, 3]], 2).unwrap();
        let prompts: Vec<Vec<i32>> = (0..4).map(|i| vec![1 + i, 2, 3]).collect();
        pipe.decode(&prompts, 2).unwrap();
        let name = format!("e2e/decode-step b4 w1 ({label})");
        let ns = ns_of(b.run(&name, || {
            black_box(pipe.decode(&prompts, 1).unwrap());
        }));
        let copy_bytes = copied_bytes(|| {
            black_box(pipe.decode(&prompts, 1).unwrap());
        });
        let read_bytes = kv_read_bytes(|| {
            black_box(pipe.decode(&prompts, 1).unwrap());
        });
        let kv = pipe.kv_stats().expect("kv stats");
        rows.push(row_step(&name, ns, copy_bytes, read_bytes, kv.blocks_in_use, 4));
        if backend == AttnBackendKind::Native {
            assert_eq!(
                copy_bytes, 0,
                "native decode step must be host-copy-free end to end"
            );
        }
        pipe.shutdown();
    }

    // continuous-batching engine vs the legacy wave driver on a mixed-
    // length trace (ISSUE 5 acceptance row): same requests, same FIFO
    // admission order, bit-identical per-request tokens — the step-driven
    // scheduler repacks retiring slots at iteration granularity while the
    // wave driver keeps the per-wave group structure, so half-empty waves
    // step alone. tokens/s is decode-phase tokens over end-to-end wall
    // clock; each driver runs twice and the faster (warm) run is scored.
    {
        let reqs: Vec<Request> = (0..12)
            .map(|i| Request {
                id: i,
                prompt_tokens: 2 + (i as usize % 5) * 3,
                gen_tokens: 2 + (i as usize % 7) * 2,
            })
            .collect();
        let mut tps = Vec::new();
        for (name, wave_mode) in [
            ("e2e/continuous-batching serve 12req mixed-len", false),
            ("e2e/serve wave-driver 12req mixed-len", true),
        ] {
            let mut pipe = DisaggPipeline::start(PipelineOpts {
                slots: 4, // small groups → real admission + repacking churn
                ..PipelineOpts::new(artifacts_dir())
            })
            .expect("pipeline");
            pipe.decode(&[vec![1, 2, 3]], 2).unwrap(); // warm the buckets
            let mut best_ns = f64::INFINITY;
            let mut mean_ns = 0.0;
            let mut tokens = 0u64;
            const RUNS: usize = 2;
            for _ in 0..RUNS {
                let t0 = std::time::Instant::now();
                let m = if wave_mode {
                    pipe.serve_waves(&reqs, 2).expect("serve")
                } else {
                    pipe.serve(&reqs, 2).expect("serve")
                };
                let ns = t0.elapsed().as_secs_f64() * 1e9;
                assert_eq!(m.requests_completed, reqs.len() as u64);
                tokens = m.tokens_generated;
                best_ns = best_ns.min(ns);
                mean_ns += ns / RUNS as f64;
            }
            pipe.shutdown();
            rows.push(row_step(name, (mean_ns, best_ns), 0, 0, 0, tokens as usize));
            tps.push(tokens as f64 / (best_ns * 1e-9));
            println!(
                "{name:<44} {best_ns:>12.0} ns/run (best)  {tokens} decode tokens  \
                 {:.1} tok/s",
                tokens as f64 / (best_ns * 1e-9)
            );
        }
        eprintln!(
            "e2e/continuous-batching vs wave driver: {:.1} vs {:.1} tok/s ({:.2}×)",
            tps[0],
            tps[1],
            tps[0] / tps[1].max(1e-9)
        );
        assert!(
            tps[0] >= tps[1] * 0.98,
            "continuous batching must not serve slower than the wave driver \
             ({:.1} vs {:.1} tok/s)",
            tps[0],
            tps[1]
        );
    }

    // shared-prefix serving (ISSUE 6 acceptance rows): 64 requests that
    // share one 48-token system prompt ahead of a unique 4-token tail,
    // served with the prefix cache off vs on. With sharing on, admission
    // maps the donor's prompt blocks copy-on-write instead of
    // re-prefilling them, so peak *physical* KV bytes must drop ≥2× at
    // unchanged logical occupancy, tokens/s must not regress, and (native
    // backend, single shard) the whole session stays host-copy-free.
    {
        const REQS: usize = 64;
        const SYS: usize = 48;
        const TAIL: usize = 4;
        // staggered decode targets (4..12) so cohorts don't finish in
        // lockstep: slots turn over continuously and every admission finds
        // a live prefilled donor in the index
        let gen_of = |i: usize| 4 + (i % 5) * 2;
        let sys_prompt: Vec<i32> = (0..SYS as i32).map(|t| 101 + t).collect();
        let prompts: Vec<Vec<i32>> = (0..REQS)
            .map(|i| {
                let mut p = sys_prompt.clone();
                p.extend((0..TAIL as i32).map(|t| 1000 + (i as i32) * 16 + t));
                p
            })
            .collect();

        // (tokens/s, peak physical B, copied B, prefix hits)
        let mut results: Vec<(f64, usize, u64, u64)> = Vec::new();
        for (name, prefix_on) in [
            ("e2e/shared-prefix serve 64req 1sysprompt (prefix-cache off)", false),
            ("e2e/shared-prefix serve 64req 1sysprompt (prefix-cache on)", true),
        ] {
            let mut pipe = DisaggPipeline::start(PipelineOpts {
                attn_workers: 1,
                attn_backend: AttnBackendKind::Native,
                slots: 8,
                kv_block_size: 4,
                prefix_cache: prefix_on,
                ..PipelineOpts::new(artifacts_dir())
            })
            .expect("pipeline");
            pipe.decode(&[vec![1, 2, 3]], 2).unwrap(); // warm the buckets
            let mut best_ns = f64::INFINITY;
            let mut mean_ns = 0.0;
            let (mut tokens, mut peak_phys, mut peak_logical) = (0u64, 0usize, 0usize);
            let (mut copied, mut hits) = (0u64, 0u64);
            const RUNS: usize = 2;
            for _ in 0..RUNS {
                pipe.begin_session(GroupMode::Packed, 2).expect("session");
                copies::reset();
                let t0 = std::time::Instant::now();
                // the prefix index holds live *prefilled* prompts, so walk
                // one donor to the decode phase before the fleet arrives —
                // a cold burst would admit together and all miss
                let donor = pipe.submit(prompts[0].clone(), gen_of(0)).expect("submit");
                while pipe.poll(donor).expect("donor live").state != RequestState::Decoding {
                    pipe.step().expect("step");
                }
                for (i, p) in prompts.iter().enumerate().skip(1) {
                    pipe.submit(p.clone(), gen_of(i)).expect("submit");
                }
                let m = pipe.drain().expect("drain");
                let ns = t0.elapsed().as_secs_f64() * 1e9;
                copied = copies::total();
                assert_eq!(m.requests_completed, REQS as u64);
                tokens = m.tokens_generated;
                peak_phys = m.kv_peak_physical_bytes();
                peak_logical = m.kv_peak_bytes();
                hits = m.prefix_hits();
                best_ns = best_ns.min(ns);
                mean_ns += ns / RUNS as f64;
                pipe.clear_finished();
            }
            pipe.shutdown();
            let tps = tokens as f64 / (best_ns * 1e-9);
            rows.push(Json::obj(vec![
                ("name", Json::str(name)),
                ("ns_per_iter", Json::num(mean_ns)),
                ("ns_per_iter_min", Json::num(best_ns)),
                ("host_copy_bytes_per_iter", Json::num(copied as f64)),
                ("kv_physical_peak_bytes", Json::num(peak_phys as f64)),
                ("kv_logical_peak_bytes", Json::num(peak_logical as f64)),
                ("prefix_hits", Json::num(hits as f64)),
                ("tokens_per_s", Json::num(tps)),
            ]));
            println!(
                "{name:<56} {best_ns:>12.0} ns/run (best)  peak physical {peak_phys} B \
                 (logical {peak_logical} B)  {hits} hits  {tps:.1} tok/s"
            );
            results.push((tps, peak_phys, copied, hits));
        }
        let (off_tps, off_phys, _off_copied, off_hits) = results[0];
        let (on_tps, on_phys, on_copied, on_hits) = results[1];
        assert_eq!(off_hits, 0, "prefix cache off must record zero hits");
        assert!(
            on_hits >= (REQS / 2) as u64,
            "shared-prefix workload must hit the prefix cache (got {on_hits} hits)"
        );
        assert_eq!(on_copied, 0, "prefix sharing must add no host copies (native backend)");
        assert!(
            on_phys * 2 <= off_phys,
            "prefix sharing must cut peak physical KV bytes ≥2× ({on_phys} vs {off_phys} B)"
        );
        assert!(
            on_tps >= off_tps,
            "prefix sharing must not serve slower ({on_tps:.1} vs {off_tps:.1} tok/s)"
        );
        eprintln!(
            "e2e/shared-prefix: prefix cache {:.2}× less peak physical KV, {:.2}× tokens/s \
             ({on_hits} hits, 0 copied bytes)",
            off_phys as f64 / on_phys.max(1) as f64,
            on_tps / off_tps.max(1e-9)
        );
    }

    // JSON substrate on a real manifest (startup path)
    let text = std::fs::read_to_string(artifacts_dir().join("manifest.json")).unwrap();
    b.run("json/parse manifest", || {
        black_box(Json::parse(&text).unwrap());
    });
}
