//! Trace-driven serving comparison (paper Fig. 10): replay all four
//! production-trace workloads against Lamina and the vLLM baseline at
//! equal hardware cost, for all three models.
//!
//!     cargo run --release --example trace_serve [-- <requests>]

fn main() -> Result<(), String> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(2000);
    let fig = lamina::figures::serving::fig10(n, 42);
    lamina::figures::save("fig10", &fig, "results").map_err(|e| e.to_string())?;

    println!();
    let f12 = lamina::figures::serving::fig12();
    lamina::figures::save("fig12", &f12, "results").map_err(|e| e.to_string())?;
    println!();
    let f14 = lamina::figures::serving::fig14();
    lamina::figures::save("fig14", &f14, "results").map_err(|e| e.to_string())?;
    println!("\nwrote results/fig10.json, results/fig12.json, results/fig14.json");
    Ok(())
}
