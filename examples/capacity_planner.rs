//! Capacity planner (paper Table 5 + Fig. 11): enumerate Lamina DOPs and
//! vLLM TP degrees for each model, simulate throughput on a trace, and
//! report cost efficiency — the tool an operator would use to choose a
//! deployment.
//!
//!     cargo run --release --example capacity_planner [-- <requests>]

fn main() -> Result<(), String> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1500);

    let t5 = lamina::figures::serving::table5();
    lamina::figures::save("table5", &t5, "results").map_err(|e| e.to_string())?;
    println!();
    let f11 = lamina::figures::serving::fig11(n, 42);
    lamina::figures::save("fig11", &f11, "results").map_err(|e| e.to_string())?;
    println!("\nwrote results/table5.json and results/fig11.json");
    Ok(())
}
