//! End-to-end serving driver (the DESIGN.md §6 validation run).
//!
//! Loads the real tiny GQA model through PJRT, spawns the disaggregated
//! pipeline (leader + 2 head-sharded attention workers + paced FHBN
//! transport), serves a trace-shaped batch of requests with continuous
//! batching and two staggered waves, and reports throughput / TBT /
//! per-component breakdown. Also runs the overlap-off ablation and the
//! NCCL-stack variant for comparison. Results land in
//! `results/e2e_serving.json` and are recorded in EXPERIMENTS.md.
//!
//!     make artifacts && cargo run --release --example e2e_serving

use lamina::netsim::stack::{FHBN, NCCL};
use lamina::trace::{synthesize, Request, AZURE_CONV};
use lamina::util::json::Json;
use lamina::util::stats::fmt_duration;
use lamina::workers::{DisaggPipeline, PipelineOpts};

fn tiny_requests(n: usize, max_ctx: usize) -> Vec<Request> {
    // Azure-Conv shape, scaled into the tiny model's context window.
    let spec = AZURE_CONV;
    let scale = (spec.mean_prompt + spec.mean_gen) / (max_ctx as f64 / 4.0);
    synthesize(&spec, n, 42)
        .into_iter()
        .map(|r| {
            let p = ((r.prompt_tokens as f64 / scale).round() as usize).clamp(1, max_ctx - 8);
            let g = ((r.gen_tokens as f64 / scale).ceil() as usize).clamp(1, max_ctx - p);
            Request { id: r.id, prompt_tokens: p, gen_tokens: g }
        })
        .collect()
}

struct RunResult {
    label: String,
    throughput: f64,
    mean_tbt: f64,
    p99_tbt: f64,
    mean_batch: f64,
    completed: u64,
}

fn run(label: &str, opts: PipelineOpts, reqs: &[Request], waves: usize) -> anyhow::Result<RunResult> {
    let mut pipe = DisaggPipeline::start(opts)?;
    let mut m = pipe.serve(reqs, waves)?;
    let r = RunResult {
        label: label.to_string(),
        throughput: m.throughput(),
        mean_tbt: m.mean_tbt(),
        p99_tbt: m.p99_tbt(),
        mean_batch: m.mean_batch(),
        completed: m.requests_completed,
    };
    let bd = m.mean_breakdown();
    println!(
        "{:<26} {:>8.1} tok/s  TBT {:>10} (p99 {:>10})  batch {:>5.2}  [model {} | attn {} | net {}]",
        r.label,
        r.throughput,
        fmt_duration(r.mean_tbt),
        fmt_duration(r.p99_tbt),
        r.mean_batch,
        fmt_duration(bd.model_s),
        fmt_duration(bd.attn_s),
        fmt_duration(bd.network_s),
    );
    pipe.shutdown();
    Ok(r)
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("LAMINA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let n: usize = std::env::var("LAMINA_E2E_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);

    // Probe config for the context window.
    let probe = DisaggPipeline::start(PipelineOpts::new(&artifacts))?;
    let cfg = probe.config().clone();
    probe.shutdown();
    let reqs = tiny_requests(n, cfg.max_seq - 1);
    let total_gen: usize = reqs.iter().map(|r| r.gen_tokens).sum();
    println!(
        "e2e serving: {} requests (Azure-Conv-shaped), {} decode tokens, model '{}' ({} params)\n",
        reqs.len(),
        total_gen,
        cfg.name,
        cfg.param_count
    );

    let mk = |overlap: bool, stack, time_scale: f64| PipelineOpts {
        overlap,
        stack,
        time_scale,
        ..PipelineOpts::new(&artifacts)
    };

    let runs = vec![
        run("FHBN + overlap (2 waves)", mk(true, &FHBN, 1.0), &reqs, 2)?,
        run("FHBN + overlap (1 wave)", mk(true, &FHBN, 1.0), &reqs, 1)?,
        run("FHBN, no overlap", mk(false, &FHBN, 1.0), &reqs, 2)?,
        run("NCCL + overlap", mk(true, &NCCL, 1.0), &reqs, 2)?,
    ];

    for r in &runs {
        assert_eq!(r.completed, reqs.len() as u64, "{} lost requests", r.label);
    }
    println!("\nall {} requests completed in every configuration ✓", reqs.len());

    let rows: Vec<Json> = runs
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("label", Json::str(r.label.clone())),
                ("throughput_tps", Json::num(r.throughput)),
                ("mean_tbt_s", Json::num(r.mean_tbt)),
                ("p99_tbt_s", Json::num(r.p99_tbt)),
                ("mean_batch", Json::num(r.mean_batch)),
            ])
        })
        .collect();
    std::fs::create_dir_all("results")?;
    std::fs::write(
        "results/e2e_serving.json",
        Json::obj(vec![
            ("experiment", Json::str("e2e_serving")),
            ("requests", Json::num(reqs.len() as f64)),
            ("decode_tokens", Json::num(total_gen as f64)),
            ("rows", Json::arr(rows)),
        ])
        .pretty(),
    )?;
    println!("wrote results/e2e_serving.json");
    Ok(())
}
