//! Automated model converter demo (paper §4.2, Fig. 6): build the decode
//! operator graph for a model shape, split it at every attention operator
//! via min-cut, and emit the Q-early slice programs — printing the cut
//! context and per-slice instruction streams.
//!
//!     cargo run --release --example model_converter

use lamina::opgraph::builder::{build_decode_graph, llama3_70b_shape, tiny_shape};
use lamina::opgraph::schedule::{emit_programs, Instr};
use lamina::opgraph::slicer::{carry_bytes, split_at_attention};

fn main() {
    for (name, shape) in [("tiny", tiny_shape()), ("LLaMA3-70B", llama3_70b_shape())] {
        let dg = build_decode_graph(shape);
        let sr = split_at_attention(&dg);
        println!(
            "== {name}: {} ops, {} edges → {} slices",
            dg.graph.nodes.len(),
            dg.graph.edges.len(),
            sr.slices.len()
        );
        for (i, cut) in sr.cuts.iter().enumerate().take(2) {
            let edges: Vec<String> = cut
                .cut_edges
                .iter()
                .map(|&e| {
                    let edge = dg.graph.edges[e];
                    format!(
                        "{} → {} ({} B)",
                        dg.graph.node(edge.src).name,
                        dg.graph.node(edge.dst).name,
                        edge.bytes
                    )
                })
                .collect();
            println!("  cut @ attention {i}: weight {} B, context = [{}]", cut.weight,
                edges.join(", "));
        }
        let carry = carry_bytes(&dg.graph, &sr.slices[1]);
        println!("  inter-slice carry: {carry} B per request");

        if name == "tiny" {
            let progs = emit_programs(&dg, &sr);
            println!("  slice 1 program (Q-early reorder):");
            for instr in &progs[1] {
                match instr {
                    Instr::Compute(v) => println!("    compute {}", dg.graph.node(*v).name),
                    Instr::SendQ { layer } => println!("    >>> SEND Q (layer {layer})"),
                    Instr::SendKV { layer } => println!("    >>> SEND KV (layer {layer})"),
                    Instr::RecvAttn { layer } => println!("    <<< RECV ATTN (layer {layer})"),
                }
            }
        }
        println!();
    }
}
