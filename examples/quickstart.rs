//! Quickstart: load the AOT-compiled tiny model and greedy-decode a prompt
//! through the full disaggregated stack (leader slices + 2 attention
//! workers + simulated network).
//!
//!     make artifacts && cargo run --release --example quickstart

use lamina::workers::{DisaggPipeline, PipelineOpts};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("LAMINA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    println!("loading artifacts from {artifacts}/ ...");
    let mut pipe = DisaggPipeline::start(PipelineOpts::new(&artifacts))?;
    let cfg = pipe.config().clone();
    println!(
        "model '{}': {} layers, d={}, {} heads ({} kv), {} params",
        cfg.name, cfg.layers, cfg.d, cfg.heads, cfg.kv_heads, cfg.param_count
    );

    let prompts: Vec<Vec<i32>> = vec![vec![1, 7, 42, 99, 3], vec![500, 2, 2, 8]];
    let steps = 12;
    let t0 = std::time::Instant::now();
    let out = pipe.decode(&prompts, steps)?;
    let dt = t0.elapsed().as_secs_f64();

    for (p, o) in prompts.iter().zip(&out) {
        println!("prompt {p:?} -> {o:?}");
    }
    let total: usize = out.iter().map(|o| o.len()).sum();
    println!(
        "{total} tokens in {:.2}s ({:.1} tok/s through the disaggregated pipeline)",
        dt,
        total as f64 / dt
    );
    let stats = pipe.engine_stats();
    println!(
        "leader engine: {} executions, {} compilations, {:.1} ms compute",
        stats.executions, stats.compilations, stats.exec_seconds * 1e3
    );
    pipe.shutdown();
    Ok(())
}
