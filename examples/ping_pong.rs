//! Network microbenchmark (paper Fig. 13): modelled ping-pong sweep for all
//! four stacks plus a live wall-clock round-trip over the in-process
//! transport to validate the data path.
//!
//!     cargo run --release --example ping_pong

fn main() {
    let fig = lamina::figures::network::fig13();
    let _ = lamina::figures::save("fig13", &fig, "results");

    println!();
    let live = lamina::figures::network::live_pingpong(65536, 100);
    let _ = lamina::figures::save("pingpong-live", &live, "results");
    println!("\nwrote results/fig13.json and results/pingpong-live.json");
}
