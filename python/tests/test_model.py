"""L2 model tests: sliced decode path vs the unsliced reference.

Proves the paper's §4.2.1 slicing is semantics-preserving (the min-cut
context {resid, q, k, v} carries everything between slices) and that the
§4.2.2 overlap path is numerically equivalent.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")

CFG = M.TINY
W = M.init_weights(CFG, seed=0)


def run_steps(step_fn, tokens, steps, **kw):
    B = tokens.shape[0]
    kc, vc = M.empty_cache(CFG, B)
    lens = jnp.zeros((B,), jnp.int32)
    cur = tokens
    logits_hist, tok_hist = [], []
    for _ in range(steps):
        logits, nxt, kc, vc, lens = step_fn(CFG, W, cur, lens, kc, vc, lens, **kw)
        logits_hist.append(np.array(logits))
        tok_hist.append(np.array(nxt))
        cur = nxt
    return logits_hist, tok_hist


class TestConfigs:
    def test_param_count_matches_init(self):
        total = 0
        total += W["embed"].size + W["final_norm"].size + W["lm_head"].size
        for lw in W["layers"]:
            total += sum(a.size for a in lw.values())
        assert total == CFG.param_count

    def test_head_geometry(self):
        assert CFG.heads % CFG.kv_heads == 0
        assert CFG.d == CFG.heads * CFG.head_dim

    @pytest.mark.parametrize("name", sorted(M.CONFIGS))
    def test_all_configs_valid(self, name):
        c = M.CONFIGS[name]
        assert c.gqa_group >= 1 and c.head_dim % 2 == 0


class TestSliceEquivalence:
    def test_sliced_matches_reference_multi_step(self):
        tokens = jnp.array([1, 7, 42], jnp.int32)
        lr, tr = run_steps(M.reference_step, tokens, 5)
        ls, ts = run_steps(M.sliced_step, tokens, 5)
        for a, b in zip(lr, ls):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
        for a, b in zip(tr, ts):
            np.testing.assert_array_equal(a, b)

    def test_overlap_path_matches(self):
        tokens = jnp.array([3, 500], jnp.int32)
        ls, ts = run_steps(M.sliced_step, tokens, 5)
        lo, to = run_steps(M.sliced_step, tokens, 5, overlap=True)
        for a, b in zip(ls, lo):
            np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)
        for a, b in zip(ts, to):
            np.testing.assert_array_equal(a, b)

    def test_batch_one(self):
        tokens = jnp.array([9], jnp.int32)
        lr, _ = run_steps(M.reference_step, tokens, 3)
        ls, _ = run_steps(M.sliced_step, tokens, 3)
        for a, b in zip(lr, ls):
            np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def test_deterministic_init(self):
        w2 = M.init_weights(CFG, seed=0)
        np.testing.assert_array_equal(W["embed"], w2["embed"])
        w3 = M.init_weights(CFG, seed=1)
        assert not np.array_equal(np.array(W["embed"]), np.array(w3["embed"]))


class TestSliceInterfaces:
    """The cut context between slices is exactly {resid, q, k, v}."""

    def test_slice_first_shapes(self):
        B = 2
        q, k, v, resid = M.slice_first(
            CFG, jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
            W["embed"], W["layers"][0]["attn_norm"], W["layers"][0]["wq"],
            W["layers"][0]["wk"], W["layers"][0]["wv"])
        assert q.shape == (B, CFG.heads, CFG.head_dim)
        assert k.shape == (B, CFG.kv_heads, CFG.head_dim)
        assert v.shape == (B, CFG.kv_heads, CFG.head_dim)
        assert resid.shape == (B, CFG.d)

    def test_slice_mid_shapes(self):
        B = 4
        q, k, v, resid = M.slice_mid(
            CFG, jnp.zeros((B, CFG.heads, CFG.head_dim)),
            jnp.zeros((B, CFG.d)), jnp.zeros((B,), jnp.int32),
            *M.layer_slice_args(W, 0))
        assert q.shape == (B, CFG.heads, CFG.head_dim)
        assert resid.shape == (B, CFG.d)

    def test_slice_last_shapes(self):
        B = 3
        lw = W["layers"][-1]
        logits, nxt = M.slice_last(
            CFG, jnp.zeros((B, CFG.heads, CFG.head_dim)),
            jnp.zeros((B, CFG.d)), lw["wo"], lw["ffn_norm"], lw["w_gate"],
            lw["w_up"], lw["w_down"], W["final_norm"], W["lm_head"])
        assert logits.shape == (B, CFG.vocab)
        assert nxt.shape == (B,) and nxt.dtype == jnp.int32

    def test_greedy_token_is_argmax(self):
        B = 2
        lw = W["layers"][-1]
        a = jax.random.normal(jax.random.PRNGKey(5), (B, CFG.heads, CFG.head_dim))
        r = jax.random.normal(jax.random.PRNGKey(6), (B, CFG.d))
        logits, nxt = M.slice_last(
            CFG, a, r, lw["wo"], lw["ffn_norm"], lw["w_gate"],
            lw["w_up"], lw["w_down"], W["final_norm"], W["lm_head"])
        np.testing.assert_array_equal(np.argmax(np.array(logits), -1), nxt)


class TestPrimitives:
    def test_rmsnorm_unit_scale(self):
        x = jnp.full((2, 8), 3.0)
        out = R.rmsnorm_ref(x, jnp.ones((8,)))
        np.testing.assert_allclose(out, 1.0, atol=1e-3)

    def test_rope_norm_preserving(self):
        """RoPE is a rotation: per-pair L2 norm is preserved."""
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 16))
        pos = jnp.array([0, 37], jnp.int32)
        out = R.rope_ref(x, pos)
        np.testing.assert_allclose(
            jnp.linalg.norm(out, axis=-1), jnp.linalg.norm(x, axis=-1),
            atol=1e-4, rtol=1e-4)

    def test_rope_pos0_identity(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 2, 8))
        out = R.rope_ref(x, jnp.zeros((1,), jnp.int32))
        np.testing.assert_allclose(out, x, atol=1e-6)

    def test_rope_relative_shift(self):
        """q·k after RoPE depends only on relative position."""
        hd = 16
        q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, hd))
        k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, hd))
        def dot_at(pq, pk):
            qr = R.rope_ref(q, jnp.array([pq], jnp.int32))
            kr = R.rope_ref(k, jnp.array([pk], jnp.int32))
            return float(jnp.sum(qr * kr))
        assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-3


class TestReferenceDecode:
    def test_prompt_teacher_forcing(self):
        outs = M.reference_decode(CFG, W, [[1, 2, 3], [9]], steps=4)
        assert len(outs) == 2 and all(len(o) == 4 for o in outs)
        assert all(0 <= t < CFG.vocab for o in outs for t in o)

    def test_decode_deterministic(self):
        a = M.reference_decode(CFG, W, [[5, 6]], steps=3)
        b = M.reference_decode(CFG, W, [[5, 6]], steps=3)
        assert a == b

    def test_batch_invariance(self):
        """A request's output must not depend on its batch-mates."""
        solo = M.reference_decode(CFG, W, [[7, 8, 9]], steps=3)[0]
        pair = M.reference_decode(CFG, W, [[7, 8, 9], [100, 100, 100]], steps=3)[0]
        assert solo == pair
