"""AOT artifact tests: manifest integrity, weight layout, HLO lowering.

These validate the build-path contract between python (producer) and the
Rust runtime (consumer) without needing the Rust side.
"""

import json
import os
import struct
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    """Build a minimal artifact set once for the module."""
    out = tmp_path_factory.mktemp("artifacts")
    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(out), "--batches", "1,2",
                "--seqs", "64", "--skip-golden"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(out / "manifest.json") as f:
        manifest = json.load(f)
    return out, manifest


class TestManifest:
    def test_config_roundtrip(self, built):
        _, m = built
        c = m["config"]
        assert c["name"] == "tiny"
        assert c["param_count"] == M.TINY.param_count
        assert c["head_dim"] == M.TINY.head_dim

    def test_entrypoint_coverage(self, built):
        _, m = built
        entries = {(e["entry"], e["batch"], e["seq"]) for e in m["entrypoints"]}
        for B in (1, 2):
            assert ("slice_first", B, None) in entries
            assert ("slice_mid", B, None) in entries
            assert ("slice_last", B, None) in entries
            assert ("attn_combine", B, None) in entries
            assert ("attention", B, 64) in entries
            assert ("attn_prev", B, 64) in entries

    def test_files_exist_and_are_hlo(self, built):
        out, m = built
        for e in m["entrypoints"]:
            p = out / e["file"]
            assert p.exists()
            head = p.read_text()[:200]
            assert "HloModule" in head

    def test_input_signatures(self, built):
        _, m = built
        for e in m["entrypoints"]:
            if e["entry"] == "attention":
                names = [i["name"] for i in e["inputs"]]
                assert names == ["q", "k_cache", "v_cache", "lens"]
                kc = e["inputs"][1]
                assert kc["shape"] == [e["batch"], M.TINY.kv_heads, 64,
                                       M.TINY.head_dim]

    def test_weight_table_layout(self, built):
        """Offsets must be contiguous and match the declared order."""
        out, m = built
        tensors = m["weights"]["tensors"]
        names = [t["name"] for t in tensors]
        assert names[:3] == ["embed", "final_norm", "lm_head"]
        assert names[3] == "layer0.attn_norm"
        expect_off = 0
        for t in tensors:
            assert t["offset"] == expect_off
            assert t["size"] == int(np.prod(t["shape"])) * 4
            expect_off += t["size"]
        assert os.path.getsize(out / "weights.bin") == expect_off

    def test_weights_bin_values(self, built):
        """weights.bin bytes must equal init_weights(seed) tensors."""
        out, m = built
        w = M.init_weights(M.TINY, seed=m["seed"])
        blob = (out / "weights.bin").read_bytes()
        t0 = next(t for t in m["weights"]["tensors"] if t["name"] == "embed")
        got = np.frombuffer(blob[t0["offset"]:t0["offset"] + t0["size"]],
                            dtype="<f4").reshape(t0["shape"])
        np.testing.assert_array_equal(got, np.asarray(w["embed"]))
        t1 = next(t for t in m["weights"]["tensors"]
                  if t["name"] == "layer1.w_down")
        got = np.frombuffer(blob[t1["offset"]:t1["offset"] + t1["size"]],
                            dtype="<f4").reshape(t1["shape"])
        np.testing.assert_array_equal(got, np.asarray(w["layers"][1]["w_down"]))


class TestHloText:
    def test_hlo_text_parses_back(self, built):
        """The emitted text must be acceptable to XLA's own parser."""
        out, m = built
        from jax._src.lib import xla_client as xc
        e = m["entrypoints"][0]
        text = (out / e["file"]).read_text()
        # ROOT of the entry computation must be a tuple (return_tuple=True)
        assert "ROOT" in text and "tuple(" in text

    def test_no_custom_calls(self, built):
        """interpret=True pallas must lower to plain HLO (no mosaic)."""
        out, m = built
        for e in m["entrypoints"]:
            text = (out / e["file"]).read_text()
            assert "custom-call" not in text, e["file"]


class TestGolden:
    def test_golden_generation(self):
        g = aot.make_golden(M.TINY, M.init_weights(M.TINY, seed=0))
        assert len(g["generated"]) == len(g["prompts"])
        assert all(len(o) == g["steps"] for o in g["generated"])
        # regeneration is deterministic
        g2 = aot.make_golden(M.TINY, M.init_weights(M.TINY, seed=0))
        assert g == g2
