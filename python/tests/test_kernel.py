"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; every test asserts allclose against ref.py.
This is the core build-time correctness signal for the attention artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as A
from compile.kernels import ref as R

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


def make_case(key, B, KH, G, S, hd, dtype=jnp.float32, min_len=1):
    H = KH * G
    q = rand(key, (B, H, hd), dtype)
    k = rand(key + 1, (B, KH, S, hd), dtype)
    v = rand(key + 2, (B, KH, S, hd), dtype)
    lens = jax.random.randint(jax.random.PRNGKey(key + 3), (B,), min_len, S + 1)
    return q, k, v, lens.astype(jnp.int32)


TOL = dict(atol=2e-5, rtol=2e-5)
BF16_TOL = dict(atol=2e-2, rtol=2e-2)


class TestDecodeAttention:
    def test_basic(self):
        q, k, v, lens = make_case(0, 4, 2, 4, 128, 32)
        out = A.decode_attention(q, k, v, lens)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    def test_mha_g1(self):
        """G=1 degenerates to plain multi-head attention (LLaMA-33B/65B)."""
        q, k, v, lens = make_case(1, 2, 8, 1, 64, 16)
        out = A.decode_attention(q, k, v, lens)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    def test_single_token_cache(self):
        q, k, v, _ = make_case(2, 3, 2, 2, 64, 16)
        lens = jnp.ones((3,), jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    def test_full_cache(self):
        q, k, v, _ = make_case(3, 2, 2, 2, 96, 16)
        lens = jnp.full((2,), 96, jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    def test_ragged_lens(self):
        """Mixed lengths in one batch — the continuous-batching case."""
        q, k, v, _ = make_case(4, 5, 2, 4, 160, 32)
        lens = jnp.array([1, 160, 77, 32, 159], jnp.int32)
        out = A.decode_attention(q, k, v, lens)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    @pytest.mark.parametrize("block_s", [16, 32, 64, 128, 999])
    def test_block_sizes(self, block_s):
        """block_s must not change numerics (chunking invariance)."""
        q, k, v, lens = make_case(5, 2, 2, 2, 128, 16)
        out = A.decode_attention(q, k, v, lens, block_s=block_s)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    def test_non_divisible_block(self):
        """S=96 with requested block 64 → falls back to a divisor."""
        q, k, v, lens = make_case(6, 2, 2, 2, 96, 16)
        out = A.decode_attention(q, k, v, lens, block_s=64)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    def test_bf16_inputs(self):
        q, k, v, lens = make_case(7, 2, 2, 4, 64, 32, dtype=jnp.bfloat16)
        out = A.decode_attention(q, k, v, lens)
        assert out.dtype == jnp.bfloat16
        ref = R.decode_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32), **BF16_TOL)

    def test_large_scores_no_overflow(self):
        """Softmax stability: huge logits must not produce inf/nan."""
        q, k, v, lens = make_case(8, 2, 2, 2, 64, 16)
        out = A.decode_attention(q * 100.0, k * 100.0, v, lens)
        assert np.isfinite(np.array(out)).all()
        ref = R.decode_attention_ref(q * 100.0, k * 100.0, v, lens)
        np.testing.assert_allclose(out, ref, **TOL)

    @settings(max_examples=40, deadline=None)
    @given(
        B=st.integers(1, 6),
        KH=st.sampled_from([1, 2, 4]),
        G=st.sampled_from([1, 2, 4, 8]),
        S=st.sampled_from([16, 48, 64, 128, 200]),
        hd=st.sampled_from([8, 16, 32, 64]),
        key=st.integers(0, 10_000),
    )
    def test_hypothesis_sweep(self, B, KH, G, S, hd, key):
        q, k, v, lens = make_case(key, B, KH, G, S, hd)
        out = A.decode_attention(q, k, v, lens)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)


class TestFlashDecode:
    def test_matches_simple(self):
        q, k, v, lens = make_case(10, 4, 2, 4, 256, 32)
        o1 = A.decode_attention(q, k, v, lens, block_s=64)
        o2 = A.decode_attention_flash(q, k, v, lens, block_s=64)
        np.testing.assert_allclose(o1, o2, **TOL)

    def test_matches_ref(self):
        q, k, v, lens = make_case(11, 3, 2, 2, 128, 16)
        out = A.decode_attention_flash(q, k, v, lens, block_s=32)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    @pytest.mark.parametrize("block_s", [16, 64, 128, 256])
    def test_grid_block_sizes(self, block_s):
        q, k, v, lens = make_case(12, 2, 2, 4, 256, 32)
        out = A.decode_attention_flash(q, k, v, lens, block_s=block_s)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    @settings(max_examples=25, deadline=None)
    @given(
        B=st.integers(1, 4),
        KH=st.sampled_from([1, 2]),
        G=st.sampled_from([1, 4]),
        S=st.sampled_from([32, 64, 128]),
        hd=st.sampled_from([16, 32]),
        key=st.integers(0, 10_000),
    )
    def test_hypothesis_sweep(self, B, KH, G, S, hd, key):
        q, k, v, lens = make_case(key, B, KH, G, S, hd)
        out = A.decode_attention_flash(q, k, v, lens, block_s=32)
        np.testing.assert_allclose(out, R.decode_attention_ref(q, k, v, lens), **TOL)

    def test_vmem_footprint_estimate(self):
        """Flash working set must fit comfortably in a 16 MiB TPU VMEM."""
        # LLaMA3-70B geometry: G=8, hd=128, S up to 32768, block 512.
        fp = A.vmem_footprint_bytes(G=8, hd=128, S=32768, block_s=512)
        assert fp < 16 * 2**20 / 4  # leave 4x headroom for the compiler


class TestPartialAttention:
    def test_matches_ref(self):
        q, k, v, lens = make_case(20, 3, 2, 4, 128, 32)
        a, s, m = A.partial_attention(q, k, v, lens)
        ar, sr, mr = R.partial_attention_ref(q, k, v, lens)
        np.testing.assert_allclose(a, ar, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(s, sr, atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(m, mr, **TOL)

    def test_combine_equals_full(self):
        """partial(cache) ⊕ new-token == full attention over cache+new.

        This is the exactness property behind the paper's §4.2.2 overlap.
        """
        B, KH, G, S, hd = 4, 2, 4, 128, 16
        q, k, v, _ = make_case(21, B, KH, G, S, hd)
        lens = jnp.array([0, 63, 100, 127], jnp.int32)  # incl. empty cache
        kn = rand(30, (B, KH, hd))
        vn = rand(31, (B, KH, hd))
        k2 = k.at[jnp.arange(B), :, lens, :].set(kn)
        v2 = v.at[jnp.arange(B), :, lens, :].set(vn)
        full = R.decode_attention_ref(q, k2, v2, lens + 1)
        a, s, m = A.partial_attention(q, k, v, lens)
        comb = A.combine_new_token(q, kn, vn, a, s, m)
        np.testing.assert_allclose(comb, full, atol=1e-4, rtol=1e-4)

    def test_combine_associative_split(self):
        """Combining partials over I1 ∪ I2 == attention over the union."""
        B, KH, G, S, hd = 2, 2, 2, 64, 16
        q = rand(40, (B, KH * G, hd))
        k1 = rand(41, (B, KH, S, hd))
        v1 = rand(42, (B, KH, S, hd))
        k2 = rand(43, (B, KH, S, hd))
        v2 = rand(44, (B, KH, S, hd))
        lens = jnp.full((B,), S, jnp.int32)
        a1, s1, m1 = R.partial_attention_ref(q, k1, v1, lens)
        a2, s2, m2 = R.partial_attention_ref(q, k2, v2, lens)
        comb = R.combine_partials_ref(a1, s1, m1, a2, s2, m2)
        kcat = jnp.concatenate([k1, k2], axis=2)
        vcat = jnp.concatenate([v1, v2], axis=2)
        full = R.decode_attention_ref(q, kcat, vcat, lens * 2)
        np.testing.assert_allclose(comb, full, atol=1e-4, rtol=1e-4)

    def test_new_token_partial_ref(self):
        B, KH, G, hd = 3, 2, 4, 16
        q = rand(50, (B, KH * G, hd))
        kn = rand(51, (B, KH, hd))
        vn = rand(52, (B, KH, hd))
        a, s, m = R.new_token_partial_ref(q, kn, vn)
        # attention over a 1-token cache == softmax of one element == v
        kc = kn[:, :, None, :]
        vc = vn[:, :, None, :]
        full = R.decode_attention_ref(q, kc, vc, jnp.ones((B,), jnp.int32))
        comb = a / s[..., None]
        np.testing.assert_allclose(comb, full, **TOL)

    @settings(max_examples=25, deadline=None)
    @given(
        B=st.integers(1, 4),
        KH=st.sampled_from([1, 2]),
        G=st.sampled_from([1, 4]),
        S=st.sampled_from([32, 128]),
        hd=st.sampled_from([16, 32]),
        key=st.integers(0, 10_000),
    )
    def test_hypothesis_combine(self, B, KH, G, S, hd, key):
        q, k, v, lens = make_case(key, B, KH, G, S, hd)
        lens = jnp.minimum(lens, S - 1)  # leave room for the new token
        lens = jnp.maximum(lens, 0)
        kn = rand(key + 7, (B, KH, hd))
        vn = rand(key + 8, (B, KH, hd))
        k2 = k.at[jnp.arange(B), :, lens, :].set(kn)
        v2 = v.at[jnp.arange(B), :, lens, :].set(vn)
        full = R.decode_attention_ref(q, k2, v2, lens + 1)
        a, s, m = A.partial_attention(q, k, v, lens)
        comb = A.combine_new_token(q, kn, vn, a, s, m)
        np.testing.assert_allclose(comb, full, atol=1e-4, rtol=1e-4)


class TestChunkedPrefill:
    def make(self, key, T, KH, G, S, hd):
        H = KH * G
        return (
            rand(key, (T, H, hd)),
            rand(key + 1, (KH, S, hd)),
            rand(key + 2, (KH, S, hd)),
            rand(key + 3, (T, KH, hd)),
            rand(key + 4, (T, KH, hd)),
        )

    @pytest.mark.parametrize("n_cached", [0, 1, 17, 64])
    def test_matches_ref(self, n_cached):
        q, kc, vc, kn, vn = self.make(70, 8, 2, 4, 64, 16)
        lens = jnp.array([n_cached], jnp.int32)
        out = A.chunked_prefill_attention(q, kc, vc, lens, kn, vn)
        ref = R.chunked_prefill_ref(q, kc, vc, lens, kn, vn)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_equals_sequential_decode(self):
        """A T-token chunk == T single-token decode steps (exactness of the
        prefill-decode transition)."""
        T, KH, G, S, hd = 6, 2, 2, 32, 16
        q, kc, vc, kn, vn = self.make(80, T, KH, G, S, hd)
        n0 = 10
        big_k = jnp.zeros((1, KH, S + T, hd)).at[0, :, :S].set(kc)
        big_v = jnp.zeros((1, KH, S + T, hd)).at[0, :, :S].set(vc)
        outs = []
        for i in range(T):
            big_k = big_k.at[0, :, n0 + i].set(kn[i])
            big_v = big_v.at[0, :, n0 + i].set(vn[i])
            o = A.decode_attention(q[i:i + 1], big_k, big_v,
                                   jnp.array([n0 + i + 1], jnp.int32))
            outs.append(o[0])
        seq = jnp.stack(outs)
        chunk = A.chunked_prefill_attention(
            q, kc, vc, jnp.array([n0], jnp.int32), kn, vn)
        np.testing.assert_allclose(chunk, seq, atol=1e-4, rtol=1e-4)

    def test_padding_rows_isolated(self):
        """Trailing (padding) chunk rows must not affect earlier outputs."""
        T, KH, G, S, hd = 8, 2, 2, 32, 16
        q, kc, vc, kn, vn = self.make(90, T, KH, G, S, hd)
        lens = jnp.array([5], jnp.int32)
        full = A.chunked_prefill_attention(q, kc, vc, lens, kn, vn)
        q2 = q.at[6:].set(999.0)
        kn2 = kn.at[6:].set(-999.0)
        vn2 = vn.at[6:].set(999.0)
        mod = A.chunked_prefill_attention(q2, kc, vc, lens, kn2, vn2)
        np.testing.assert_allclose(full[:6], mod[:6], atol=1e-5, rtol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        T=st.sampled_from([1, 4, 8]),
        KH=st.sampled_from([1, 2]),
        G=st.sampled_from([1, 4]),
        S=st.sampled_from([32, 64]),
        n=st.integers(0, 32),
        key=st.integers(0, 10_000),
    )
    def test_hypothesis_sweep(self, T, KH, G, S, n, key):
        q, kc, vc, kn, vn = self.make(key, T, KH, G, S, 16)
        lens = jnp.array([min(n, S)], jnp.int32)
        out = A.chunked_prefill_attention(q, kc, vc, lens, kn, vn)
        ref = R.chunked_prefill_ref(q, kc, vc, lens, kn, vn)
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)


class TestInterpretVsJit:
    def test_kernel_inside_jit_graph(self):
        """The kernel must lower inside a bigger jitted graph (the L2 path)."""
        q, k, v, lens = make_case(60, 2, 2, 2, 64, 16)

        @jax.jit
        def f(q, k, v, lens):
            return A.decode_attention(q, k, v, lens) * 2.0

        out = f(q, k, v, lens)
        np.testing.assert_allclose(
            out, R.decode_attention_ref(q, k, v, lens) * 2.0, **TOL)
