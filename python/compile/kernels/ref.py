"""Pure-jnp correctness oracles for the Lamina attention kernels.

These are the ground truth used by pytest/hypothesis to validate the Pallas
kernels in `attention.py` and by `model.py` tests for the sliced decode step.
Everything here is deliberately straightforward jnp — no pallas, no tricks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # avoid actual -inf so masked softmax stays NaN-free


def decode_attention_ref(q, k_cache, v_cache, lens):
    """Reference GQA decode attention.

    Args:
      q:        [B, H, hd]   queries for the current token.
      k_cache:  [B, KH, S, hd] key cache (first ``lens[b]`` rows valid).
      v_cache:  [B, KH, S, hd] value cache.
      lens:     [B] int32, number of valid cached tokens per request.

    Returns:
      [B, H, hd] attention output.
    """
    B, H, hd = q.shape
    _, KH, S, _ = k_cache.shape
    G = H // KH
    qr = q.reshape(B, KH, G, hd).astype(jnp.float32)
    kc = k_cache.astype(jnp.float32)
    vc = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qr, kc) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.arange(S)[None, None, None, :] < lens[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", w, vc)
    return out.reshape(B, H, hd).astype(q.dtype)


def partial_attention_ref(q, k_cache, v_cache, lens):
    """Reference for the *partial* attention used by the overlap path.

    Computes the max-stabilised partial softmax state over the cached tokens:
      m = max_j s_j            (running max, [B, H])
      S = sum_j exp(s_j - m)   (stabilised denominator, [B, H])
      A = sum_j exp(s_j - m) v_j   (stabilised numerator, [B, H, hd])

    The paper's §4.2.2 combines raw [A, S]; we carry ``m`` as well for
    numerical stability — combining is exact either way.
    """
    B, H, hd = q.shape
    _, KH, S, _ = k_cache.shape
    G = H // KH
    qr = q.reshape(B, KH, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qr, k_cache.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    mask = jnp.arange(S)[None, None, None, :] < lens[:, None, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                       # [B, KH, G]
    e = jnp.exp(scores - m[..., None])
    e = jnp.where(mask, e, 0.0)
    s = jnp.sum(e, axis=-1)                            # [B, KH, G]
    a = jnp.einsum("bkgs,bksd->bkgd", e, v_cache.astype(jnp.float32))
    return (
        a.reshape(B, H, hd),
        s.reshape(B, H),
        m.reshape(B, H),
    )


def combine_partials_ref(a1, s1, m1, a2, s2, m2):
    """Combine two max-stabilised partial attention states (paper §4.2.2).

    A_q(I1 ∪ I2) = (A1·S1 + A2·S2) / (S1 + S2) in the paper's un-stabilised
    notation; with per-partial running maxes m1, m2 the exact form is below.
    """
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    s = s1 * c1 + s2 * c2
    a = a1 * c1[..., None] + a2 * c2[..., None]
    return a / s[..., None]


def new_token_partial_ref(q, k_new, v_new):
    """Partial softmax state for the single newly-generated token.

    Args:
      q:     [B, H, hd]
      k_new: [B, KH, hd]
      v_new: [B, KH, hd]

    Returns (A, S, m) with shapes ([B,H,hd], [B,H], [B,H]).
    """
    B, H, hd = q.shape
    _, KH, _ = k_new.shape
    G = H // KH
    qr = q.reshape(B, KH, G, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bkd->bkg", qr, k_new.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))                  # [B, KH, G]
    m = s                                              # single element: max == score
    one = jnp.ones_like(s)                             # exp(s - m) == 1
    a = jnp.broadcast_to(
        v_new.astype(jnp.float32)[:, :, None, :], (B, KH, G, hd)
    )
    return (
        a.reshape(B, H, hd),
        one.reshape(B, H),
        m.reshape(B, H),
    )


def chunked_prefill_ref(q, k_cache, v_cache, lens, k_new, v_new):
    """Reference for the chunked-prefill attention (one request).

    q: [T, H, hd]; k_cache/v_cache: [KH, S, hd]; lens: [1];
    k_new/v_new: [T, KH, hd]. Each chunk token i attends cache[:lens] and
    chunk tokens 0..i.
    """
    T, H, hd = q.shape
    KH, S, _ = k_cache.shape
    G = H // KH
    n = lens[0]
    # build the full K/V the chunk sees: cache then chunk
    kc = jnp.concatenate([k_cache, jnp.transpose(k_new, (1, 0, 2))], axis=1)
    vc = jnp.concatenate([v_cache, jnp.transpose(v_new, (1, 0, 2))], axis=1)
    qr = q.reshape(T, KH, G, hd).astype(jnp.float32)
    scores = jnp.einsum("tkgd,ksd->tkgs", qr, kc.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    pos = jnp.arange(S + T)
    ti = jnp.arange(T)
    mask = (pos[None, :] < n) | (
        (pos[None, :] >= S) & (pos[None, :] - S <= ti[:, None])
    )
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("tkgs,ksd->tkgd", w, vc.astype(jnp.float32))
    return out.reshape(T, H, hd).astype(q.dtype)


def rmsnorm_ref(x, w, eps=1e-5):
    """RMSNorm: x * w / sqrt(mean(x^2) + eps)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps) * w).astype(x.dtype)


def rope_ref(x, pos, theta=10000.0):
    """Rotary position embedding over the last dim of x: [B, n, hd], pos: [B]."""
    B, n, hd = x.shape
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]       # [B, half]
    cos = jnp.cos(ang)[:, None, :]                                # [B, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
