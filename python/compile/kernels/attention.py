"""L1 — Pallas decode-attention kernels for Lamina.

The paper's attention worker runs the memory-bound batched-GEMV (BGEMV) decode
attention on memory-optimised devices. On TPU-style hardware (see DESIGN.md
§Hardware-Adaptation) we express it as a Pallas kernel:

* grid over ``(batch, kv_head)`` — each program owns one request's one KV head
  group, turning the per-request BGEMV into a thin ``G×hd @ hd×S`` GEMM that
  maps onto MXU tiles (GQA raises arithmetic intensity G×, paper §2.2.2);
* the KV sequence is streamed through VMEM in ``block_s`` chunks with an
  online-softmax accumulator — the HBM→VMEM schedule the paper's CUDA kernel
  expressed with threadblocks;
* a *flash* variant additionally tiles the sequence onto the grid with VMEM
  scratch accumulators (double-buffered HBM streaming on real TPUs).

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; real-TPU performance is estimated analytically in
DESIGN.md / EXPERIMENTS.md from VMEM footprint and MXU utilisation.

Two extra entry points support the paper's resource-utilisation overlapping
(§4.2.2): ``partial_attention`` returns the max-stabilised softmax state
``[A, S, m]`` over the *cached* tokens only (computable as soon as ``q``
arrives at the attention worker), and ``combine_new_token`` folds in the
freshly projected ``k_new/v_new`` when they arrive later.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
DEFAULT_BLOCK_S = 128


def _online_softmax_chunks(q, k, v, valid_len, seq_len, block_s):
    """Shared online-softmax inner loop over VMEM-resident K/V.

    q: [G, hd], k/v: [S, hd]; returns (acc [G, hd], s [G], m [G]) —
    the *stabilised* partial state (acc and s are scaled by exp(-m)).
    """
    G, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    nblk = seq_len // block_s

    def body(i, carry):
        acc, s, m = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * block_s, block_s, axis=0)
        vb = jax.lax.dynamic_slice_in_dim(v, i * block_s, block_s, axis=0)
        scores = jnp.dot(q, kb.T) * scale                     # [G, block_s]
        idx = i * block_s + jax.lax.iota(jnp.int32, block_s)
        mask = idx[None, :] < valid_len
        scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(scores, axis=1))       # [G]
        corr = jnp.exp(m - m_new)
        e = jnp.exp(scores - m_new[:, None])
        e = jnp.where(mask, e, 0.0)
        s_new = s * corr + jnp.sum(e, axis=1)
        acc_new = acc * corr[:, None] + jnp.dot(e, vb)
        return acc_new, s_new, m_new

    init = (
        jnp.zeros((G, hd), jnp.float32),
        jnp.zeros((G,), jnp.float32),
        jnp.full((G,), NEG_INF, jnp.float32),
    )
    return jax.lax.fori_loop(0, nblk, body, init)


def _attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, block_s):
    """Full decode attention for one (batch, kv_head) program."""
    q = q_ref[0, 0].astype(jnp.float32)                       # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)                       # [S, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    n = len_ref[0]
    acc, s, _ = _online_softmax_chunks(q, k, v, n, k.shape[0], block_s)
    o_ref[0, 0] = (acc / s[:, None]).astype(o_ref.dtype)


def _partial_kernel(q_ref, k_ref, v_ref, len_ref, a_ref, s_ref, m_ref, *, block_s):
    """Partial (unnormalised, max-stabilised) attention over cached tokens."""
    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    n = len_ref[0]
    acc, s, m = _online_softmax_chunks(q, k, v, n, k.shape[0], block_s)
    a_ref[0, 0] = acc.astype(a_ref.dtype)
    s_ref[0, 0] = s.astype(s_ref.dtype)
    m_ref[0, 0] = m.astype(m_ref.dtype)


def _pick_block_s(seq_len, block_s):
    """Largest divisor of seq_len that is <= requested block size."""
    b = min(block_s, seq_len)
    while seq_len % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention(q, k_cache, v_cache, lens, *, block_s=DEFAULT_BLOCK_S,
                     interpret=True):
    """GQA decode attention via the Pallas kernel.

    Args:
      q:       [B, H, hd]     current-token queries.
      k_cache: [B, KH, S, hd] key cache (rows >= lens[b] ignored).
      v_cache: [B, KH, S, hd] value cache.
      lens:    [B] int32      valid cache length per request.
      block_s: sequence chunk streamed through the online-softmax loop.

    Returns [B, H, hd] attention outputs (same dtype as q).
    """
    B, H, hd = q.shape
    _, KH, S, _ = k_cache.shape
    assert H % KH == 0, "query heads must be divisible by kv heads"
    G = H // KH
    bs = _pick_block_s(S, block_s)
    out = pl.pallas_call(
        functools.partial(_attn_kernel, block_s=bs),
        grid=(B, KH),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        interpret=interpret,
    )(q.reshape(B, KH, G, hd), k_cache, v_cache, lens)
    return out.reshape(B, H, hd)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def partial_attention(q, k_cache, v_cache, lens, *, block_s=DEFAULT_BLOCK_S,
                      interpret=True):
    """Partial attention over the cached tokens only (overlap path, §4.2.2).

    Returns the max-stabilised state ``(A, S, m)`` with shapes
    ``([B,H,hd], [B,H], [B,H])`` such that the full attention equals
    ``combine(new_token_partial(q, k_new, v_new), (A, S, m))``.
    """
    B, H, hd = q.shape
    _, KH, S, _ = k_cache.shape
    G = H // KH
    bs = _pick_block_s(S, block_s)
    a, s, m = pl.pallas_call(
        functools.partial(_partial_kernel, block_s=bs),
        grid=(B, KH),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, G), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KH, G, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, KH, G), jnp.float32),
            jax.ShapeDtypeStruct((B, KH, G), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B, KH, G, hd), k_cache, v_cache, lens)
    return a.reshape(B, H, hd), s.reshape(B, H), m.reshape(B, H)


def combine_new_token(q, k_new, v_new, a_prev, s_prev, m_prev):
    """Fold the newly generated token into a partial attention state.

    Pure-jnp (the work is O(B·H·hd); not worth a kernel). This is the second
    half of the paper's divide-and-conquer attention:

      A_q(I) = (A_q(prev)·S_q(prev) + A_q(new)·S_q(new)) / (S_q(prev)+S_q(new))

    computed in max-stabilised form.
    """
    B, H, hd = q.shape
    _, KH, _ = k_new.shape
    G = H // KH
    qf = q.reshape(B, KH, G, hd).astype(jnp.float32)
    s_new = jnp.einsum("bkgd,bkd->bkg", qf, k_new.astype(jnp.float32))
    s_new = (s_new / jnp.sqrt(jnp.float32(hd))).reshape(B, H)
    m = jnp.maximum(m_prev, s_new)
    c_prev = jnp.exp(m_prev - m)
    c_new = jnp.exp(s_new - m)
    denom = s_prev * c_prev + c_new
    v_rep = jnp.broadcast_to(
        v_new.astype(jnp.float32)[:, :, None, :], (B, KH, G, hd)
    ).reshape(B, H, hd)
    num = a_prev * c_prev[..., None] + v_rep * c_new[..., None]
    return (num / denom[..., None]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash variant: sequence tiled on the grid with VMEM scratch accumulators.
# This is the shape a real-TPU deployment would use (double-buffered HBM
# streaming driven by BlockSpec); numerics are identical to decode_attention.
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, acc_ref, s_ref, m_ref,
                  *, block_s, nblk):
    sb = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)                       # [G, hd]
    k = k_ref[0, 0].astype(jnp.float32)                       # [block_s, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    n = len_ref[0]

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        s_ref[...] = jnp.zeros_like(s_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)

    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.dot(q, k.T) * scale                          # [G, block_s]
    idx = sb * block_s + jax.lax.iota(jnp.int32, block_s)
    mask = idx[None, :] < n
    scores = jnp.where(mask, scores, NEG_INF)

    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, jnp.max(scores, axis=1))
    corr = jnp.exp(m_old - m_new)
    e = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
    s_ref[...] = s_ref[...] * corr + jnp.sum(e, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(e, v)
    m_ref[...] = m_new

    @pl.when(sb == nblk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / s_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def decode_attention_flash(q, k_cache, v_cache, lens, *,
                           block_s=DEFAULT_BLOCK_S, interpret=True):
    """Flash-decode attention: sequence blocks on the grid, scratch in VMEM."""
    import jax.experimental.pallas.tpu as pltpu

    B, H, hd = q.shape
    _, KH, S, _ = k_cache.shape
    G = H // KH
    bs = _pick_block_s(S, block_s)
    nblk = S // bs
    out = pl.pallas_call(
        functools.partial(_flash_kernel, block_s=bs, nblk=nblk),
        grid=(B, KH, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, bs, hd), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1,), lambda b, h, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B, KH, G, hd), k_cache, v_cache, lens)
    return out.reshape(B, H, hd)


# ---------------------------------------------------------------------------
# Chunked prefill attention (paper §5, "handling the prefill-decode
# transition"): a chunk of T prompt tokens attends (a) the already-cached
# prefix and (b) causally within the chunk. One request per call (B = 1);
# the coordinator schedules chunks between decode steps so KV streaming
# interferes minimally with decoding (Sarathi-style piggybacking).
# ---------------------------------------------------------------------------

def _prefill_kernel(q_ref, kc_ref, vc_ref, len_ref, kn_ref, vn_ref, o_ref, *,
                    block_s):
    """One (kv_head,) program: q [T, G, hd] over cache [S, hd] + chunk."""
    q = q_ref[0].astype(jnp.float32)                 # [T, G, hd]
    kc = kc_ref[0].astype(jnp.float32)               # [S, hd]
    vc = vc_ref[0].astype(jnp.float32)
    kn = kn_ref[0].astype(jnp.float32)               # [T, hd]
    vn = vn_ref[0].astype(jnp.float32)
    n = len_ref[0]
    T, G, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qf = q.reshape(T * G, hd)

    # cached-prefix partial (shared mask per chunk row)
    acc, s, m = _online_softmax_chunks(qf, kc, vc, n, kc.shape[0], block_s)

    # intra-chunk causal part
    scores = jnp.dot(qf, kn.T) * scale               # [T*G, T]
    ti = jax.lax.iota(jnp.int32, T * G) // G         # chunk row of each query
    tj = jax.lax.iota(jnp.int32, T)
    mask = tj[None, :] <= ti[:, None]                # causal within chunk
    scores = jnp.where(mask, scores, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(scores, axis=1))
    corr = jnp.exp(m - m_new)
    e = jnp.where(mask, jnp.exp(scores - m_new[:, None]), 0.0)
    s = s * corr + jnp.sum(e, axis=1)
    acc = acc * corr[:, None] + jnp.dot(e, vn)

    o_ref[0] = (acc / s[:, None]).reshape(T, G, hd).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def chunked_prefill_attention(q, k_cache, v_cache, lens, k_new, v_new, *,
                              block_s=DEFAULT_BLOCK_S, interpret=True):
    """Prefill a chunk of T tokens for ONE request.

    Args:
      q:       [T, H, hd]      chunk queries (RoPE applied).
      k_cache: [KH, S, hd]     cached keys (first ``lens`` rows valid).
      v_cache: [KH, S, hd]
      lens:    [1] int32       valid cached tokens (before this chunk).
      k_new:   [T, KH, hd]     chunk keys.
      v_new:   [T, KH, hd]     chunk values.

    Returns [T, H, hd]: each chunk token attends the cached prefix plus the
    chunk's own causal prefix.
    """
    T, H, hd = q.shape
    KH, S, _ = k_cache.shape
    G = H // KH
    bs = _pick_block_s(S, block_s)
    # regroup: [KH, T, G, hd] so the grid maps one kv head per program
    qg = jnp.transpose(q.reshape(T, KH, G, hd), (1, 0, 2, 3))
    kn = jnp.transpose(k_new, (1, 0, 2))             # [KH, T, hd]
    vn = jnp.transpose(v_new, (1, 0, 2))
    out = pl.pallas_call(
        functools.partial(_prefill_kernel, block_s=bs),
        grid=(KH,),
        in_specs=[
            pl.BlockSpec((1, T, G, hd), lambda h: (h, 0, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1,), lambda h: (0,)),
            pl.BlockSpec((1, T, hd), lambda h: (h, 0, 0)),
            pl.BlockSpec((1, T, hd), lambda h: (h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, T, G, hd), lambda h: (h, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((KH, T, G, hd), q.dtype),
        interpret=interpret,
    )(qg, k_cache, v_cache, lens, kn, vn)
    return jnp.transpose(out, (1, 0, 2, 3)).reshape(T, H, hd)


def vmem_footprint_bytes(G, hd, S, block_s, dtype_bytes=2):
    """Estimated VMEM working set of one flash-decode program on a real TPU.

    q tile + double-buffered K and V blocks + fp32 accumulators. Used by the
    perf analysis in EXPERIMENTS.md (interpret mode has no real VMEM).
    """
    q_tile = G * hd * dtype_bytes
    kv_blocks = 2 * 2 * block_s * hd * dtype_bytes  # K+V, double-buffered
    acc = (G * hd + 2 * G) * 4
    return q_tile + kv_blocks + acc
