"""L2 — LLaMA-style decode-step model, pre-split for model-attention
disaggregation (paper §4.2.1).

Cutting the decode step at every attention operator yields ``L+1`` slices.
All middle slices are structurally identical, so we lower three HLO entry
points and bind per-layer weights at call time from the Rust coordinator:

* ``slice_first`` — embed → RMSNorm → QKV projection (layer 0) → RoPE.
* ``slice_mid``   — O-proj (layer i) → +residual → SwiGLU FFN → +residual →
                    RMSNorm → QKV projection (layer i+1) → RoPE.
* ``slice_last``  — O-proj (layer L-1) → +residual → FFN → final RMSNorm →
                    LM head → greedy next token.

The cut context between slices is exactly ``{residual stream x, q, k, v}``:
the min-cut the automated converter finds on the operator graph (asserted by
``rust/src/opgraph`` tests). The attention operator itself lives in
``kernels/attention.py`` (L1) and is lowered into its own artifacts executed
by the *attention workers*; the slices run on the *model workers*.

Weights are plain pytrees of jnp arrays; ``init_weights`` produces a
deterministic random model, and ``reference_decode`` is the unsliced oracle
the sliced path is tested against.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels.ref import rmsnorm_ref, rope_ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (LLaMA-style, GQA)."""

    name: str
    vocab: int
    d: int            # hidden dim
    layers: int
    heads: int        # query heads H
    kv_heads: int     # KV heads KH; G = H / KH
    ffn: int          # SwiGLU hidden dim
    max_seq: int      # KV-cache capacity (per seq bucket)
    rope_theta: float = 10000.0
    eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d % self.heads == 0
        return self.d // self.heads

    @property
    def gqa_group(self) -> int:
        assert self.heads % self.kv_heads == 0
        return self.heads // self.kv_heads

    @property
    def param_count(self) -> int:
        """Exact parameter count for this config."""
        hd = self.head_dim
        per_layer = (
            self.d * self.heads * hd          # Wq
            + 2 * self.d * self.kv_heads * hd  # Wk, Wv
            + self.heads * hd * self.d        # Wo
            + 3 * self.d * self.ffn           # Wgate, Wup, Wdown
            + 2 * self.d                      # attn_norm, ffn_norm
        )
        return (
            self.vocab * self.d               # embedding
            + self.layers * per_layer
            + self.d                          # final norm
            + self.d * self.vocab             # LM head
        )


# Named configs. `tiny` is what `make artifacts` AOT-compiles and the Rust
# e2e example actually serves; the Table-3 models exist as *analytical*
# configs for the roofline simulator (their HLO is never materialised here).
TINY = ModelConfig(name="tiny", vocab=512, d=128, layers=4, heads=8,
                   kv_heads=2, ffn=256, max_seq=256)
SMALL = ModelConfig(name="small", vocab=2048, d=256, layers=8, heads=16,
                    kv_heads=4, ffn=768, max_seq=512)
CONFIGS = {c.name: c for c in (TINY, SMALL)}


# ---------------------------------------------------------------------------
# Weight init
# ---------------------------------------------------------------------------

def init_weights(cfg: ModelConfig, seed: int = 0) -> Dict[str, Any]:
    """Deterministic random-init weights, scaled for stable decoding."""
    key = jax.random.PRNGKey(seed)
    hd = cfg.head_dim

    def nrm(key, shape, scale):
        return (jax.random.normal(key, shape, jnp.float32) * scale)

    keys = iter(jax.random.split(key, 8 + 8 * cfg.layers))
    w: Dict[str, Any] = {
        "embed": nrm(next(keys), (cfg.vocab, cfg.d), 1.0),
        "final_norm": jnp.ones((cfg.d,), jnp.float32),
        "lm_head": nrm(next(keys), (cfg.d, cfg.vocab), cfg.d ** -0.5),
        "layers": [],
    }
    for _ in range(cfg.layers):
        w["layers"].append({
            "attn_norm": jnp.ones((cfg.d,), jnp.float32),
            "wq": nrm(next(keys), (cfg.d, cfg.heads * hd), cfg.d ** -0.5),
            "wk": nrm(next(keys), (cfg.d, cfg.kv_heads * hd), cfg.d ** -0.5),
            "wv": nrm(next(keys), (cfg.d, cfg.kv_heads * hd), cfg.d ** -0.5),
            "wo": nrm(next(keys), (cfg.heads * hd, cfg.d), cfg.d ** -0.5),
            "ffn_norm": jnp.ones((cfg.d,), jnp.float32),
            "w_gate": nrm(next(keys), (cfg.d, cfg.ffn), cfg.d ** -0.5),
            "w_up": nrm(next(keys), (cfg.d, cfg.ffn), cfg.d ** -0.5),
            "w_down": nrm(next(keys), (cfg.ffn, cfg.d), cfg.ffn ** -0.5),
        })
    return w


# Flat, ordered per-layer weight names — the binary layout contract shared
# with aot.py (manifest) and the Rust weight loader.
LAYER_WEIGHT_NAMES = ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm",
                      "w_gate", "w_up", "w_down")
GLOBAL_WEIGHT_NAMES = ("embed", "final_norm", "lm_head")


# ---------------------------------------------------------------------------
# Model slices (the HLO entry points)
# ---------------------------------------------------------------------------

def _qkv(cfg: ModelConfig, x, pos, attn_norm, wq, wk, wv):
    """RMSNorm → QKV proj → RoPE. Shared tail of slice_first/slice_mid."""
    hd = cfg.head_dim
    B = x.shape[0]
    h = rmsnorm_ref(x, attn_norm, cfg.eps)
    q = (h @ wq).reshape(B, cfg.heads, hd)
    k = (h @ wk).reshape(B, cfg.kv_heads, hd)
    v = (h @ wv).reshape(B, cfg.kv_heads, hd)
    q = rope_ref(q, pos, cfg.rope_theta)
    k = rope_ref(k, pos, cfg.rope_theta)
    return q, k, v


def _ffn(cfg: ModelConfig, x, ffn_norm, w_gate, w_up, w_down):
    """Pre-norm SwiGLU FFN with residual."""
    h = rmsnorm_ref(x, ffn_norm, cfg.eps)
    return x + (jax.nn.silu(h @ w_gate) * (h @ w_up)) @ w_down


def slice_first(cfg: ModelConfig, tokens, pos, embed, attn_norm, wq, wk, wv):
    """tokens [B] i32, pos [B] i32 → (q, k_new, v_new, resid)."""
    x = embed[tokens]                       # [B, d]
    q, k, v = _qkv(cfg, x, pos, attn_norm, wq, wk, wv)
    return q, k, v, x


def slice_mid(cfg: ModelConfig, attn_out, resid, pos,
              wo, ffn_norm, w_gate, w_up, w_down,
              attn_norm_next, wq_next, wk_next, wv_next):
    """attn_out [B,H,hd], resid [B,d] → (q, k_new, v_new, resid')."""
    B = resid.shape[0]
    x = resid + attn_out.reshape(B, -1) @ wo
    x = _ffn(cfg, x, ffn_norm, w_gate, w_up, w_down)
    q, k, v = _qkv(cfg, x, pos, attn_norm_next, wq_next, wk_next, wv_next)
    return q, k, v, x


def slice_last(cfg: ModelConfig, attn_out, resid,
               wo, ffn_norm, w_gate, w_up, w_down, final_norm, lm_head):
    """attn_out [B,H,hd], resid [B,d] → (logits [B,V], next_token [B] i32)."""
    B = resid.shape[0]
    x = resid + attn_out.reshape(B, -1) @ wo
    x = _ffn(cfg, x, ffn_norm, w_gate, w_up, w_down)
    x = rmsnorm_ref(x, final_norm, cfg.eps)
    logits = x @ lm_head
    return logits, jnp.argmax(logits, axis=-1).astype(jnp.int32)


def layer_slice_args(w: Dict[str, Any], i: int) -> List[Any]:
    """Weights for slice_mid joining attention-layer i to layer i+1."""
    li, ln = w["layers"][i], w["layers"][i + 1]
    return [li["wo"], li["ffn_norm"], li["w_gate"], li["w_up"], li["w_down"],
            ln["attn_norm"], ln["wq"], ln["wk"], ln["wv"]]


# ---------------------------------------------------------------------------
# Reference decode (unsliced oracle)
# ---------------------------------------------------------------------------

def reference_step(cfg: ModelConfig, w, tokens, pos, k_cache, v_cache, lens):
    """One unsliced decode step. Returns (logits, next_token, k_cache',
    v_cache', lens')."""
    B = tokens.shape[0]
    x = w["embed"][tokens]
    for i, lw in enumerate(w["layers"]):
        q, k_new, v_new = _qkv(cfg, x, pos, lw["attn_norm"], lw["wq"],
                               lw["wk"], lw["wv"])
        k_cache = k_cache.at[i, jnp.arange(B), :, lens, :].set(k_new)
        v_cache = v_cache.at[i, jnp.arange(B), :, lens, :].set(v_new)
        a = attn_k.decode_attention(q, k_cache[i], v_cache[i], lens + 1)
        x = x + a.reshape(B, -1) @ lw["wo"]
        x = _ffn(cfg, x, lw["ffn_norm"], lw["w_gate"], lw["w_up"], lw["w_down"])
    x = rmsnorm_ref(x, w["final_norm"], cfg.eps)
    logits = x @ w["lm_head"]
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return logits, nxt, k_cache, v_cache, lens + 1


def empty_cache(cfg: ModelConfig, batch: int):
    shape = (cfg.layers, batch, cfg.kv_heads, cfg.max_seq, cfg.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def reference_decode(cfg: ModelConfig, w, prompts: List[List[int]],
                     steps: int) -> List[List[int]]:
    """Greedy-decode ``steps`` tokens for each prompt; returns generated ids.

    Prompts are consumed token-by-token through the decode path (no separate
    prefill kernel — prefill is out of scope per the paper's evaluation,
    which removes the prefill phase from both systems).
    """
    B = len(prompts)
    k_cache, v_cache = empty_cache(cfg, B)
    lens = jnp.zeros((B,), jnp.int32)
    maxp = max(len(p) for p in prompts)
    out: List[List[int]] = [[] for _ in range(B)]
    cur = jnp.array([p[0] for p in prompts], jnp.int32)
    for t in range(maxp + steps - 1):
        pos = lens
        _, nxt, k_cache, v_cache, lens = reference_step(
            cfg, w, cur, pos, k_cache, v_cache, lens)
        nxt_list = []
        for b, p in enumerate(prompts):
            if t + 1 < len(p):
                nxt_list.append(p[t + 1])          # still teacher-forcing prompt
            else:
                tok = int(nxt[b])
                if len(out[b]) < steps:
                    out[b].append(tok)
                nxt_list.append(tok)
        cur = jnp.array(nxt_list, jnp.int32)
    return out


def sliced_step(cfg: ModelConfig, w, tokens, pos, k_cache, v_cache, lens,
                overlap: bool = False):
    """One decode step through the *sliced* path (first/mid/last + attention).

    Mirrors exactly what the Rust coordinator does, including the overlap
    variant that computes partial attention over the cache before folding in
    the new token (paper §4.2.2). Used by tests to prove slice equivalence.
    """
    B = tokens.shape[0]
    q, k_new, v_new, resid = slice_first(
        cfg, tokens, pos, w["embed"], w["layers"][0]["attn_norm"],
        w["layers"][0]["wq"], w["layers"][0]["wk"], w["layers"][0]["wv"])
    for i in range(cfg.layers):
        if overlap:
            a_p, s_p, m_p = attn_k.partial_attention(q, k_cache[i], v_cache[i], lens)
            a = attn_k.combine_new_token(q, k_new, v_new, a_p, s_p, m_p)
            k_cache = k_cache.at[i, jnp.arange(B), :, lens, :].set(k_new)
            v_cache = v_cache.at[i, jnp.arange(B), :, lens, :].set(v_new)
        else:
            k_cache = k_cache.at[i, jnp.arange(B), :, lens, :].set(k_new)
            v_cache = v_cache.at[i, jnp.arange(B), :, lens, :].set(v_new)
            a = attn_k.decode_attention(q, k_cache[i], v_cache[i], lens + 1)
        if i + 1 < cfg.layers:
            q, k_new, v_new, resid = slice_mid(
                cfg, a, resid, pos, *layer_slice_args(w, i))
        else:
            lw = w["layers"][i]
            logits, nxt = slice_last(
                cfg, a, resid, lw["wo"], lw["ffn_norm"], lw["w_gate"],
                lw["w_up"], lw["w_down"], w["final_norm"], w["lm_head"])
    return logits, nxt, k_cache, v_cache, lens + 1
