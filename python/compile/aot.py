"""AOT compile path: lower every Lamina entry point to HLO **text** and dump
weights + a JSON manifest for the Rust runtime.

Run once at build time (``make artifacts``); Python never appears on the
serving path. Interchange is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``) — the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``artifacts/``):

* ``<entry>.b<B>[.s<S>].hlo.txt`` — one HLO module per (entry point, batch
  bucket[, seq bucket]); the Rust runtime compiles each once and caches the
  executable (continuous batching pads to the nearest bucket).
* ``weights.bin`` — all weights, little-endian f32, order given by manifest.
* ``manifest.json`` — config, weight table (name/shape/offset), entry-point
  I/O signatures, bucket lists.
* ``golden.json`` — greedy-decoded token ids for fixed prompts, the oracle
  for the Rust integration test.
"""

from __future__ import annotations

import argparse
import functools
import json
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import attention as A

BATCH_BUCKETS = [1, 2, 4, 8]
SEQ_BUCKETS = [16, 64, 256]
# Head-level attention sharding (paper §5): worker counts the attention
# artifacts are lowered for. Worker w of W owns kv_heads/W KV heads and the
# matching G·kv_heads/W query heads; shapes shrink accordingly.
SHARD_COUNTS = [1, 2]
GOLDEN_PROMPTS = [[1, 7, 42, 99, 3], [500, 2, 2, 8], [13, 255]]
GOLDEN_STEPS = 16


def to_hlo_text(lowered) -> str:
    """jax Lowered → XLA HLO text via stablehlo (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args: List[Any]) -> List[Dict[str, Any]]:
    return [
        {"shape": list(a.shape), "dtype": str(a.dtype)}
        for a in args
    ]


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entrypoints(cfg: M.ModelConfig, batches, seqs):
    """Yield (name, batch, seq, fn, example_args, input_names)."""
    hd, H, KH, d, V = cfg.head_dim, cfg.heads, cfg.kv_heads, cfg.d, cfg.vocab
    f32, i32 = jnp.float32, jnp.int32

    for B in batches:
        yield (
            "slice_first", B, None,
            functools.partial(M.slice_first, cfg),
            [_spec((B,), i32), _spec((B,), i32), _spec((V, d)),
             _spec((d,)), _spec((d, H * hd)), _spec((d, KH * hd)),
             _spec((d, KH * hd))],
            ["tokens", "pos", "embed", "attn_norm", "wq", "wk", "wv"],
            ["q", "k_new", "v_new", "resid"],
        )
        yield (
            "slice_mid", B, None,
            functools.partial(M.slice_mid, cfg),
            [_spec((B, H, hd)), _spec((B, d)), _spec((B,), i32),
             _spec((H * hd, d)), _spec((d,)), _spec((d, cfg.ffn)),
             _spec((d, cfg.ffn)), _spec((cfg.ffn, d)),
             _spec((d,)), _spec((d, H * hd)), _spec((d, KH * hd)),
             _spec((d, KH * hd))],
            ["attn_out", "resid", "pos", "wo", "ffn_norm", "w_gate", "w_up",
             "w_down", "attn_norm_next", "wq_next", "wk_next", "wv_next"],
            ["q", "k_new", "v_new", "resid"],
        )
        yield (
            "slice_last", B, None,
            functools.partial(M.slice_last, cfg),
            [_spec((B, H, hd)), _spec((B, d)),
             _spec((H * hd, d)), _spec((d,)), _spec((d, cfg.ffn)),
             _spec((d, cfg.ffn)), _spec((cfg.ffn, d)),
             _spec((d,)), _spec((d, V))],
            ["attn_out", "resid", "wo", "ffn_norm", "w_gate", "w_up",
             "w_down", "final_norm", "lm_head"],
            ["logits", "next_token"],
        )
        for W in SHARD_COUNTS:
            # shard shapes: worker owns KH/W kv heads → H/W query heads
            assert KH % W == 0, "shard count must divide kv heads"
            khs, hs = KH // W, H // W
            sfx = "" if W == 1 else f"_w{W}"
            yield (
                f"attn_combine{sfx}", B, None,
                A.combine_new_token,
                [_spec((B, hs, hd)), _spec((B, khs, hd)), _spec((B, khs, hd)),
                 _spec((B, hs, hd)), _spec((B, hs)), _spec((B, hs))],
                ["q", "k_new", "v_new", "a_prev", "s_prev", "m_prev"],
                ["attn_out"],
            )
            # chunked prefill (paper §5): one request, chunk of T = B tokens
            for S in seqs:
                yield (
                    f"prefill_attn{sfx}", B, S,
                    lambda q, kc, vc, l, kn, vn: A.chunked_prefill_attention(
                        q, kc, vc, l, kn, vn),
                    [_spec((B, hs, hd)), _spec((khs, S, hd)),
                     _spec((khs, S, hd)), _spec((1,), i32),
                     _spec((B, khs, hd)), _spec((B, khs, hd))],
                    ["q", "k_cache", "v_cache", "lens", "k_new", "v_new"],
                    ["attn_out"],
                )
            for S in seqs:
                yield (
                    f"attention{sfx}", B, S,
                    lambda q, kc, vc, l: A.decode_attention(q, kc, vc, l),
                    [_spec((B, hs, hd)), _spec((B, khs, S, hd)),
                     _spec((B, khs, S, hd)), _spec((B,), i32)],
                    ["q", "k_cache", "v_cache", "lens"],
                    ["attn_out"],
                )
                yield (
                    f"attn_prev{sfx}", B, S,
                    lambda q, kc, vc, l: A.partial_attention(q, kc, vc, l),
                    [_spec((B, hs, hd)), _spec((B, khs, S, hd)),
                     _spec((B, khs, S, hd)), _spec((B,), i32)],
                    ["q", "k_cache", "v_cache", "lens"],
                    ["a_prev", "s_prev", "m_prev"],
                )


def artifact_name(entry: str, batch: int, seq) -> str:
    if seq is None:
        return f"{entry}.b{batch}.hlo.txt"
    return f"{entry}.b{batch}.s{seq}.hlo.txt"


def dump_weights(cfg: M.ModelConfig, w, path: str):
    """Write weights.bin and return the manifest tensor table."""
    tensors = []
    offset = 0
    flat: List[np.ndarray] = []

    def add(name, arr):
        nonlocal offset
        a = np.asarray(arr, dtype=np.float32)
        tensors.append({
            "name": name,
            "shape": list(a.shape),
            "dtype": "f32",
            "offset": offset,
            "size": a.size * 4,
        })
        flat.append(a)
        offset += a.size * 4

    for name in M.GLOBAL_WEIGHT_NAMES:
        add(name, w[name])
    for i, lw in enumerate(w["layers"]):
        for name in M.LAYER_WEIGHT_NAMES:
            add(f"layer{i}.{name}", lw[name])

    with open(path, "wb") as f:
        for a in flat:
            f.write(a.tobytes())
    return tensors


def make_golden(cfg: M.ModelConfig, w) -> Dict[str, Any]:
    outs = M.reference_decode(cfg, w, GOLDEN_PROMPTS, GOLDEN_STEPS)
    return {"prompts": GOLDEN_PROMPTS, "steps": GOLDEN_STEPS,
            "generated": outs}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--config", default="tiny", choices=sorted(M.CONFIGS))
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--batches", default=",".join(map(str, BATCH_BUCKETS)))
    p.add_argument("--seqs", default=",".join(map(str, SEQ_BUCKETS)))
    p.add_argument("--skip-golden", action="store_true")
    args = p.parse_args()

    cfg = M.CONFIGS[args.config]
    batches = [int(x) for x in args.batches.split(",")]
    seqs = [int(x) for x in args.seqs.split(",")]
    assert all(s <= cfg.max_seq for s in seqs)
    os.makedirs(args.out_dir, exist_ok=True)

    w = M.init_weights(cfg, seed=args.seed)
    tensors = dump_weights(cfg, w, os.path.join(args.out_dir, "weights.bin"))
    print(f"weights.bin: {sum(t['size'] for t in tensors)} bytes, "
          f"{len(tensors)} tensors ({cfg.param_count} params)")

    entries = []
    for entry, B, S, fn, specs, in_names, out_names in build_entrypoints(
            cfg, batches, seqs):
        def as_tuple(*a, _fn=fn):
            out = _fn(*a)
            return out if isinstance(out, tuple) else (out,)

        lowered = jax.jit(as_tuple).lower(*specs)
        text = to_hlo_text(lowered)
        fname = artifact_name(entry, B, S)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entries.append({
            "entry": entry, "batch": B, "seq": S, "file": fname,
            "inputs": [dict(n, name=nm) for n, nm in zip(_sig(specs), in_names)],
            "outputs": out_names,
        })
        print(f"  {fname}: {len(text)} chars")

    manifest = {
        "format_version": 1,
        "config": {
            "name": cfg.name, "vocab": cfg.vocab, "d": cfg.d,
            "layers": cfg.layers, "heads": cfg.heads,
            "kv_heads": cfg.kv_heads, "ffn": cfg.ffn,
            "max_seq": cfg.max_seq, "head_dim": cfg.head_dim,
            "rope_theta": cfg.rope_theta, "eps": cfg.eps,
            "param_count": cfg.param_count,
        },
        "seed": args.seed,
        "buckets": {"batch": batches, "seq": seqs},
        "weights": {"file": "weights.bin", "tensors": tensors},
        "layer_weight_names": list(M.LAYER_WEIGHT_NAMES),
        "global_weight_names": list(M.GLOBAL_WEIGHT_NAMES),
        "entrypoints": entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)

    if not args.skip_golden:
        golden = make_golden(cfg, w)
        with open(os.path.join(args.out_dir, "golden.json"), "w") as f:
            json.dump(golden, f)
        print(f"golden.json: {len(golden['prompts'])} prompts × "
              f"{golden['steps']} steps")
    print(f"manifest.json: {len(entries)} entry points")


if __name__ == "__main__":
    main()
