#!/usr/bin/env python3
"""Bench regression guard for the decode hot path.

Compares the freshly generated ``rust/BENCH_decode.json`` against this
machine's entry in the committed ``rust/BENCH_baseline.json`` and fails
when the decode path got slower or started moving bytes again:

* **ns/iter**: any decode-path row (``kv/``, ``kernel/``, ``e2e/``,
  ``host/``, ``obs/`` prefixes) more than 20% slower than baseline fails
  (the ``obs/`` rows pin the observability layer's overhead contract —
  the tighter ≤2% raw-vs-instrumented bound is asserted inside the bench
  binary itself, where both sides run back to back). Rows are
  gated on ``ns_per_iter_min`` when both sides carry it (the min of a
  sample run is far more jitter-robust than the mean — the ROADMAP PR-3
  follow-up), falling back to mean ``ns_per_iter`` against old baselines.
  A small absolute slack (250 ns) keeps sub-microsecond rows from tripping
  on scheduler noise in quick mode.
* **copied bytes**: ``host_copy_bytes_per_iter`` may never *increase* for
  any row — machine-independent, gates the zero-copy invariant (the
  paged-native decode step stays at **zero** copied KV bytes).
* **read bytes**: ``kv_read_bytes_per_iter`` may never increase either —
  this pins the quantized-storage win (the ``kv=f16``/``kv=int8`` rows'
  2×/≈4× per-step bytes-read reduction can't silently regress; the
  absolute ≥1.8×/≥3× ratios are asserted inside the bench binary itself).

Bench numbers are machine-specific, so baselines are stored **per
machine**, keyed by hostname::

    {"format": "per-machine-v1", "machines": {"runner-a": {...rows...}}}

The first run on a machine bootstraps its own entry (other machines'
entries are untouched), so the never-grows gates stay meaningful on shared
CI runners where jobs land on different hosts. Legacy single-machine
baseline files (a bare ``{"rows": [...]}`` doc) are migrated in place: a
measured legacy doc becomes the current host's entry; a bootstrap marker
just becomes the empty per-machine skeleton. ``--update`` rewrites this
machine's entry explicitly.

Usage: bench_guard.py BASELINE CURRENT [--update]
"""

import json
import socket
import sys

NS_REGRESSION = 1.20  # fail if > 20% slower
NS_SLACK = 250.0      # ignore sub-noise absolute deltas (quick-mode jitter)
NS_PREFIXES = (
    "kv/", "kernel/", "e2e/", "host/", "obs/", "failover/",
    "net/frame-batch", "net/mux-step",
)
FORMAT = "per-machine-v1"
NOTE = (
    "Per-machine bench baselines (keyed by hostname). Bench numbers are "
    "machine-specific: the first scripts/check.sh run on a host fills in "
    "that host's entry from rust/BENCH_decode.json; later runs on the same "
    "host gate decode-path ns/iter (>20% regression fails) and per-step "
    "copied/read bytes (any increase fails) against it. Use "
    "`scripts/bench_guard.py ... --update` after an intentional perf change."
)
# Row families renamed when the kv-dtype sweep landed (PR 4): an old
# measured baseline may still carry these names; they migrate with a note
# instead of failing the "row disappeared" check. Any OTHER vanished row
# still fails, whatever schema the baseline has.
RENAMED_ROWS = (
    "kv/append 32 tokens + retire (paged)",
    "kernel/decode-step paged-native b",
)
# byte-exact gates: (field, human label)
BYTE_FIELDS = (
    ("host_copy_bytes_per_iter", "copied bytes"),
    ("kv_read_bytes_per_iter", "KV bytes read"),
    ("kv_physical_peak_bytes", "peak physical KV bytes"),
)


def hostname():
    return socket.gethostname() or "unknown-host"


def rows_by_name(doc):
    return {r["name"]: r for r in doc.get("rows", [])}


def gate_ns(base, cur):
    """Pick the (value, statistic) pair to gate on: min when both rows have
    it, else mean (old baselines predate ns_per_iter_min)."""
    if "ns_per_iter_min" in base and "ns_per_iter_min" in cur:
        return float(base["ns_per_iter_min"]), float(cur["ns_per_iter_min"]), "min"
    return float(base["ns_per_iter"]), float(cur["ns_per_iter"]), "mean"


def load_baseline(path, host):
    """Load the baseline file; return (whole_doc, this_host_entry, migrated).

    Handles the per-machine format, legacy single-machine docs (migrated
    to this host's entry when they carry measured rows — `migrated` is
    True so the caller rewrites the file in the new format), and missing
    or corrupt files (fresh skeleton).
    """
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        doc = None

    skeleton = {"format": FORMAT, "note": NOTE, "machines": {}}
    if doc is None:
        return skeleton, None, False
    if isinstance(doc.get("machines"), dict):
        doc.setdefault("format", FORMAT)
        doc.setdefault("note", NOTE)
        return doc, doc["machines"].get(host), False
    # legacy single-machine file
    if doc.get("bootstrap") or not doc.get("rows"):
        return skeleton, None, False
    entry = {k: v for k, v in doc.items() if k not in ("bootstrap", "note")}
    skeleton["machines"][host] = entry
    print(f"bench_guard: migrated legacy baseline to per-machine entry '{host}'")
    return skeleton, entry, True


def write(path, doc):
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    baseline_path, current_path = argv[1], argv[2]
    update = "--update" in argv[3:]
    host = hostname()

    with open(current_path) as f:
        current = json.load(f)

    doc, entry, migrated = load_baseline(baseline_path, host)

    if update or entry is None or not entry.get("rows"):
        fresh = dict(current)
        fresh.pop("bootstrap", None)
        doc["machines"][host] = fresh
        write(baseline_path, doc)
        why = "--update" if update else "bootstrap (no measured baseline for this host yet)"
        print(f"bench_guard: wrote baseline for '{host}' in {baseline_path} ({why})")
        return 0

    base_rows = rows_by_name(entry)
    cur_rows = rows_by_name(current)
    failures = []
    checked = 0
    new_rows = []

    for name, cur in cur_rows.items():
        base = base_rows.get(name)
        if base is None:
            new_rows.append(cur)  # no baseline yet: adopt below, gate next run
            continue
        checked += 1

        if name.startswith(NS_PREFIXES):
            b_ns, c_ns, stat = gate_ns(base, cur)
            if c_ns > b_ns * NS_REGRESSION and c_ns - b_ns > NS_SLACK:
                failures.append(
                    f"{name}: {c_ns:.0f} ns/iter ({stat}) vs baseline {b_ns:.0f} "
                    f"(+{(c_ns / b_ns - 1) * 100:.1f}% > {round((NS_REGRESSION - 1) * 100)}%)"
                )

        for field, label in BYTE_FIELDS:
            b_bytes = base.get(field)
            c_bytes = cur.get(field)
            if b_bytes is not None and c_bytes is not None and float(c_bytes) > float(b_bytes):
                failures.append(
                    f"{name}: {label} grew {int(float(b_bytes))} -> {int(float(c_bytes))}"
                )

    # e2e/* rows are artifact-gated (benches skip them when rust/artifacts/
    # is absent) — their absence is an environment difference, not a
    # regression, so only warn. Artifact-free rows must never vanish —
    # EXCEPT the specific RENAMED_ROWS families from a pre-`ns_per_iter_min`
    # baseline (`kv/append … (paged)` → `…, kv=f32)`, `kernel/decode-step
    # paged-native b…` → `… kv=f32 b…`): those migrate with a note instead
    # of hard-failing check.sh, and the stale entries are dropped so they
    # don't warn forever. A genuinely deleted bench still fails.
    stale = []
    for name in sorted(set(base_rows) - set(cur_rows)):
        if name.startswith("e2e/"):
            print(f"bench_guard: note — artifact-gated row missing (no artifacts?): {name}")
        elif "ns_per_iter_min" not in base_rows[name] and name.startswith(RENAMED_ROWS):
            print(f"bench_guard: note — row renamed in the kv-dtype sweep, dropping: {name}")
            stale.append(name)
        else:
            failures.append(f"{name}: row disappeared from the bench output")

    if failures:
        print(f"bench_guard: {len(failures)} regression(s) over {checked} compared rows "
              f"(host '{host}'):")
        for f_ in failures:
            print(f"  FAIL {f_}")
        print("(rerun with --update after an intentional change)")
        return 1

    if new_rows or stale or migrated:
        # adopt rows that have no baseline entry yet so they are gated from
        # the next run on (and say so — silence would unguard new benches),
        # drop schema-migrated stale names, and persist a legacy→per-machine
        # format migration
        for r in new_rows:
            print(f"bench_guard: adopting new row into '{host}' baseline: {r['name']}")
            entry["rows"].append(r)
        if stale:
            entry["rows"] = [r for r in entry["rows"] if r["name"] not in stale]
        doc["machines"][host] = entry
        write(baseline_path, doc)

    print(f"bench_guard: OK — {checked} rows within bounds on '{host}', no byte growth")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
