#!/usr/bin/env python3
"""Bench regression guard for the decode hot path.

Compares the freshly generated ``rust/BENCH_decode.json`` against the
committed ``rust/BENCH_baseline.json`` and fails when the decode path got
slower or started copying again:

* **ns/iter**: any decode-path row (``kv/``, ``kernel/``, ``e2e/``,
  ``host/`` prefixes) more than 20% slower than baseline fails. A small
  absolute slack (250 ns) keeps sub-microsecond rows from tripping on
  scheduler noise in quick mode.
* **copied bytes**: ``host_copy_bytes_per_iter`` may never *increase* for
  any row — this is machine-independent and gates the tentpole invariant
  (the paged-native decode step stays at **zero** copied KV bytes).

Bench numbers are machine-specific, so the repo ships a ``bootstrap``
baseline; the first run on a machine fills it with measured rows and later
runs gate against them. ``--update`` rewrites the baseline explicitly.

Usage: bench_guard.py BASELINE CURRENT [--update]
"""

import json
import sys

NS_REGRESSION = 1.20  # fail if > 20% slower
NS_SLACK = 250.0      # ignore sub-noise absolute deltas (quick-mode jitter)
NS_PREFIXES = ("kv/", "kernel/", "e2e/", "host/")


def rows_by_name(doc):
    return {r["name"]: r for r in doc.get("rows", [])}


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    baseline_path, current_path = argv[1], argv[2]
    update = "--update" in argv[3:]

    with open(current_path) as f:
        current = json.load(f)

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError):
        baseline = None

    if update or baseline is None or baseline.get("bootstrap") or not baseline.get("rows"):
        current = dict(current)
        current.pop("bootstrap", None)
        with open(baseline_path, "w") as f:
            json.dump(current, f, indent=2)
            f.write("\n")
        why = "--update" if update else "bootstrap (no measured baseline yet)"
        print(f"bench_guard: wrote baseline {baseline_path} ({why})")
        return 0

    base_rows = rows_by_name(baseline)
    cur_rows = rows_by_name(current)
    failures = []
    checked = 0
    new_rows = []

    for name, cur in cur_rows.items():
        base = base_rows.get(name)
        if base is None:
            new_rows.append(cur)  # no baseline yet: adopt below, gate next run
            continue
        checked += 1

        if name.startswith(NS_PREFIXES):
            b_ns, c_ns = float(base["ns_per_iter"]), float(cur["ns_per_iter"])
            if c_ns > b_ns * NS_REGRESSION and c_ns - b_ns > NS_SLACK:
                failures.append(
                    f"{name}: {c_ns:.0f} ns/iter vs baseline {b_ns:.0f} "
                    f"(+{(c_ns / b_ns - 1) * 100:.1f}% > {round((NS_REGRESSION - 1) * 100)}%)"
                )

        b_copy = base.get("host_copy_bytes_per_iter")
        c_copy = cur.get("host_copy_bytes_per_iter")
        if b_copy is not None and c_copy is not None and float(c_copy) > float(b_copy):
            failures.append(
                f"{name}: copied bytes grew {int(float(b_copy))} -> {int(float(c_copy))}"
            )

    # e2e/* rows are artifact-gated (benches skip them when rust/artifacts/
    # is absent) — their absence is an environment difference, not a
    # regression, so only warn. Artifact-free rows must never vanish.
    for name in sorted(set(base_rows) - set(cur_rows)):
        if name.startswith("e2e/"):
            print(f"bench_guard: note — artifact-gated row missing (no artifacts?): {name}")
        else:
            failures.append(f"{name}: row disappeared from the bench output")

    if failures:
        print(f"bench_guard: {len(failures)} regression(s) over {checked} compared rows:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        print("(rerun with --update after an intentional change)")
        return 1

    if new_rows:
        # adopt rows that have no baseline entry yet so they are gated from
        # the next run on (and say so — silence would unguard new benches)
        for r in new_rows:
            print(f"bench_guard: adopting new row into baseline: {r['name']}")
            baseline["rows"].append(r)
        with open(baseline_path, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")

    print(f"bench_guard: OK — {checked} rows within bounds, no copy growth")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
