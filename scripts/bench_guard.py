#!/usr/bin/env python3
"""Bench regression guard for the decode hot path.

Compares the freshly generated ``rust/BENCH_decode.json`` against the
committed ``rust/BENCH_baseline.json`` and fails when the decode path got
slower or started moving bytes again:

* **ns/iter**: any decode-path row (``kv/``, ``kernel/``, ``e2e/``,
  ``host/`` prefixes) more than 20% slower than baseline fails. Rows are
  gated on ``ns_per_iter_min`` when both sides carry it (the min of a
  sample run is far more jitter-robust than the mean — the ROADMAP PR-3
  follow-up), falling back to mean ``ns_per_iter`` against old baselines.
  A small absolute slack (250 ns) keeps sub-microsecond rows from tripping
  on scheduler noise in quick mode.
* **copied bytes**: ``host_copy_bytes_per_iter`` may never *increase* for
  any row — machine-independent, gates the zero-copy invariant (the
  paged-native decode step stays at **zero** copied KV bytes).
* **read bytes**: ``kv_read_bytes_per_iter`` may never increase either —
  this pins the quantized-storage win (the ``kv=f16``/``kv=int8`` rows'
  2×/≈4× per-step bytes-read reduction can't silently regress; the
  absolute ≥1.8×/≥3× ratios are asserted inside the bench binary itself).

Bench numbers are machine-specific, so the repo ships a ``bootstrap``
baseline; the first run on a machine fills it with measured rows and later
runs gate against them. ``--update`` rewrites the baseline explicitly.

Usage: bench_guard.py BASELINE CURRENT [--update]
"""

import json
import sys

NS_REGRESSION = 1.20  # fail if > 20% slower
NS_SLACK = 250.0      # ignore sub-noise absolute deltas (quick-mode jitter)
NS_PREFIXES = ("kv/", "kernel/", "e2e/", "host/")
# Row families renamed when the kv-dtype sweep landed (PR 4): an old
# measured baseline may still carry these names; they migrate with a note
# instead of failing the "row disappeared" check. Any OTHER vanished row
# still fails, whatever schema the baseline has.
RENAMED_ROWS = (
    "kv/append 32 tokens + retire (paged)",
    "kernel/decode-step paged-native b",
)
# byte-exact gates: (field, human label)
BYTE_FIELDS = (
    ("host_copy_bytes_per_iter", "copied bytes"),
    ("kv_read_bytes_per_iter", "KV bytes read"),
)


def rows_by_name(doc):
    return {r["name"]: r for r in doc.get("rows", [])}


def gate_ns(base, cur):
    """Pick the (value, statistic) pair to gate on: min when both rows have
    it, else mean (old baselines predate ns_per_iter_min)."""
    if "ns_per_iter_min" in base and "ns_per_iter_min" in cur:
        return float(base["ns_per_iter_min"]), float(cur["ns_per_iter_min"]), "min"
    return float(base["ns_per_iter"]), float(cur["ns_per_iter"]), "mean"


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 2
    baseline_path, current_path = argv[1], argv[2]
    update = "--update" in argv[3:]

    with open(current_path) as f:
        current = json.load(f)

    try:
        with open(baseline_path) as f:
            baseline = json.load(f)
    except (OSError, json.JSONDecodeError):
        baseline = None

    if update or baseline is None or baseline.get("bootstrap") or not baseline.get("rows"):
        current = dict(current)
        current.pop("bootstrap", None)
        with open(baseline_path, "w") as f:
            json.dump(current, f, indent=2)
            f.write("\n")
        why = "--update" if update else "bootstrap (no measured baseline yet)"
        print(f"bench_guard: wrote baseline {baseline_path} ({why})")
        return 0

    base_rows = rows_by_name(baseline)
    cur_rows = rows_by_name(current)
    failures = []
    checked = 0
    new_rows = []

    for name, cur in cur_rows.items():
        base = base_rows.get(name)
        if base is None:
            new_rows.append(cur)  # no baseline yet: adopt below, gate next run
            continue
        checked += 1

        if name.startswith(NS_PREFIXES):
            b_ns, c_ns, stat = gate_ns(base, cur)
            if c_ns > b_ns * NS_REGRESSION and c_ns - b_ns > NS_SLACK:
                failures.append(
                    f"{name}: {c_ns:.0f} ns/iter ({stat}) vs baseline {b_ns:.0f} "
                    f"(+{(c_ns / b_ns - 1) * 100:.1f}% > {round((NS_REGRESSION - 1) * 100)}%)"
                )

        for field, label in BYTE_FIELDS:
            b_bytes = base.get(field)
            c_bytes = cur.get(field)
            if b_bytes is not None and c_bytes is not None and float(c_bytes) > float(b_bytes):
                failures.append(
                    f"{name}: {label} grew {int(float(b_bytes))} -> {int(float(c_bytes))}"
                )

    # e2e/* rows are artifact-gated (benches skip them when rust/artifacts/
    # is absent) — their absence is an environment difference, not a
    # regression, so only warn. Artifact-free rows must never vanish —
    # EXCEPT the specific RENAMED_ROWS families from a pre-`ns_per_iter_min`
    # baseline (`kv/append … (paged)` → `…, kv=f32)`, `kernel/decode-step
    # paged-native b…` → `… kv=f32 b…`): those migrate with a note instead
    # of hard-failing check.sh, and the stale entries are dropped so they
    # don't warn forever. A genuinely deleted bench still fails.
    stale = []
    for name in sorted(set(base_rows) - set(cur_rows)):
        if name.startswith("e2e/"):
            print(f"bench_guard: note — artifact-gated row missing (no artifacts?): {name}")
        elif "ns_per_iter_min" not in base_rows[name] and name.startswith(RENAMED_ROWS):
            print(f"bench_guard: note — row renamed in the kv-dtype sweep, dropping: {name}")
            stale.append(name)
        else:
            failures.append(f"{name}: row disappeared from the bench output")

    if failures:
        print(f"bench_guard: {len(failures)} regression(s) over {checked} compared rows:")
        for f_ in failures:
            print(f"  FAIL {f_}")
        print("(rerun with --update after an intentional change)")
        return 1

    if new_rows or stale:
        # adopt rows that have no baseline entry yet so they are gated from
        # the next run on (and say so — silence would unguard new benches),
        # and drop schema-migrated stale names
        for r in new_rows:
            print(f"bench_guard: adopting new row into baseline: {r['name']}")
            baseline["rows"].append(r)
        if stale:
            baseline["rows"] = [r for r in baseline["rows"] if r["name"] not in stale]
        with open(baseline_path, "w") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")

    print(f"bench_guard: OK — {checked} rows within bounds, no byte growth")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
