#!/usr/bin/env python3
"""Validate a Lamina Chrome trace_event file (``lamina ... --trace-out``).

Checks the structural contract Perfetto/chrome://tracing rely on, so CI
catches a malformed exporter before a human ever loads a trace:

* the file is valid JSON with a non-empty ``traceEvents`` array;
* every event carries ``name``/``ph``/``ts``/``pid``/``tid`` with sane
  types; complete events (``ph == "X"``) carry a non-negative ``dur``;
* per ``tid`` (obs track = one thread), complete spans obey stack
  discipline: sorted by start time, a span either nests inside the
  enclosing open span or starts after it ends — partial overlap means the
  span tree is corrupt;
* spans are recorded at drop time, so per-track *end* timestamps must be
  nondecreasing in capture order (the monotone-clock invariant);
* ``thread_name`` metadata names every track that has events;
* the expected category vocabulary is present (``--require-cats``,
  default ``leader,wire,worker,kernel`` — pass an empty string to skip,
  e.g. for single-process traces with no worker).

Usage: validate_trace.py TRACE.json [--require-cats leader,wire,...]

Exits non-zero with a description of the first violation. Stdlib only.
"""

import json
import sys

# span end-vs-sibling-start measurements come from separate clock reads;
# allow a microsecond of slop before calling the nesting corrupt
TOL_US = 1.0

DEFAULT_CATS = "leader,wire,worker,kernel"


def fail(msg):
    print(f"validate_trace: FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    opts = [a for a in sys.argv[1:] if a.startswith("--")]
    if len(args) != 1:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    require_cats = DEFAULT_CATS
    for o in opts:
        if o.startswith("--require-cats"):
            require_cats = o.split("=", 1)[1] if "=" in o else ""
        else:
            fail(f"unknown option {o}")

    path = args[0]
    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"{path}: {e}")

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents missing or empty")

    named_tracks = set()
    tracks = {}  # tid -> list of (ts, dur, name) complete spans, capture order
    last_end = {}  # tid -> last recorded end timestamp (capture order)
    cats = set()
    n_spans = 0
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fail(f"event {i} is not an object")
        ph = e.get("ph")
        name = e.get("name")
        if not isinstance(name, str) or not name:
            fail(f"event {i} has no name")
        if not isinstance(e.get("ts"), (int, float)):
            fail(f"event {i} ({name}) has no numeric ts")
        if "pid" not in e or "tid" not in e:
            fail(f"event {i} ({name}) missing pid/tid")
        tid = e["tid"]
        if ph == "M":
            if name == "thread_name":
                named_tracks.add(tid)
            continue
        if "cat" in e:
            cats.add(e["cat"])
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"span {name} (event {i}) has bad dur {dur!r}")
            tracks.setdefault(tid, []).append((e["ts"], dur, name))
            end = e["ts"] + dur
            prev = last_end.get(tid)
            if prev is not None and end < prev - TOL_US:
                fail(
                    f"track {tid}: span {name} ends at {end} before the "
                    f"previously recorded end {prev} (drop order broken)"
                )
            last_end[tid] = max(prev, end) if prev is not None else end
            n_spans += 1
        elif ph == "i":
            if e.get("s") not in (None, "t", "p", "g"):
                fail(f"instant {name} has bad scope {e.get('s')!r}")
        else:
            fail(f"event {i} ({name}) has unsupported phase {ph!r}")

    if n_spans == 0:
        fail("no complete ('X') spans in trace")

    for tid, spans in tracks.items():
        if tid not in named_tracks:
            fail(f"track {tid} has spans but no thread_name metadata")
        # stack discipline per track: sort by start, keep a stack of open
        # span end times; a span must close before its enclosing span does
        spans = sorted(spans, key=lambda s: s[0])
        stack = []
        for ts, dur, name in spans:
            end = ts + dur
            while stack and ts >= stack[-1] - TOL_US:
                stack.pop()
            if stack and end > stack[-1] + TOL_US:
                fail(
                    f"track {tid}: span {name} [{ts}, {end}] straddles the "
                    f"enclosing span's end {stack[-1]}"
                )
            stack.append(end)

    if require_cats:
        want = {c.strip() for c in require_cats.split(",") if c.strip()}
        missing = want - cats
        if missing:
            fail(f"missing categories {sorted(missing)} (have {sorted(cats)})")

    print(
        f"validate_trace: OK: {len(events)} events, {n_spans} spans on "
        f"{len(tracks)} track(s), cats {sorted(cats)}"
    )


if __name__ == "__main__":
    main()
