#!/usr/bin/env bash
# Repo-wide verification gate: release build, full test suite, the obs
# trace-emission smoke (an artifact-free scripted session must export a
# Perfetto-parseable trace — happy path AND worker-death truncation), the
# bench suite in quick mode (which regenerates rust/BENCH_decode.json with
# codec GB/s, TCP-loopback RTT, KV-gather, native-kernel decode-step and
# obs-overhead rows), and the bench regression guard (decode-path ns/iter
# must stay within 20% of rust/BENCH_baseline.json and per-step copied
# bytes may never grow — in particular the paged-native decode step must
# stay at ZERO copied KV bytes).
#
# Usage: scripts/check.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== trace-emission smoke (exporter + validator) =="
TRACE_TMP="$(mktemp -d)"
trap 'rm -rf "$TRACE_TMP"' EXIT
target/release/lamina trace-smoke --steps 6 --trace-out "$TRACE_TMP/trace.json"
python3 scripts/validate_trace.py "$TRACE_TMP/trace.json"
# a worker dying mid-session must still leave a well-formed (truncated) trace
target/release/lamina trace-smoke --steps 6 --kill-worker \
  --trace-out "$TRACE_TMP/trace-kill.json"
python3 scripts/validate_trace.py "$TRACE_TMP/trace-kill.json"

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== cargo bench (LAMINA_BENCH_QUICK=1) =="
  LAMINA_BENCH_QUICK=1 cargo bench
  echo "bench output: rust/BENCH_decode.json"

  echo "== bench regression guard =="
  python3 scripts/bench_guard.py rust/BENCH_baseline.json rust/BENCH_decode.json
fi

echo "check.sh: all green"
