#!/usr/bin/env bash
# Repo-wide verification gate: release build, full test suite, the bench
# suite in quick mode (which regenerates rust/BENCH_decode.json with codec
# GB/s, TCP-loopback RTT, KV-gather and native-kernel decode-step rows),
# and the bench regression guard (decode-path ns/iter must stay within 20%
# of rust/BENCH_baseline.json and per-step copied bytes may never grow —
# in particular the paged-native decode step must stay at ZERO copied KV
# bytes).
#
# Usage: scripts/check.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== cargo bench (LAMINA_BENCH_QUICK=1) =="
  LAMINA_BENCH_QUICK=1 cargo bench
  echo "bench output: rust/BENCH_decode.json"

  echo "== bench regression guard =="
  python3 scripts/bench_guard.py rust/BENCH_baseline.json rust/BENCH_decode.json
fi

echo "check.sh: all green"
