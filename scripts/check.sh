#!/usr/bin/env bash
# Repo-wide verification gate: release build, full test suite, the obs
# trace-emission smoke (an artifact-free scripted session must export a
# Perfetto-parseable trace — happy path AND worker-death truncation), the
# fault-matrix smoke (chaos sessions with worker kills at prefill,
# mid-decode, and the drain tail, over both transports — each must recover
# bit-identically with zero leaked KV blocks), the bench suite in quick mode (which regenerates rust/BENCH_decode.json with
# codec GB/s, TCP-loopback RTT, KV-gather, native-kernel decode-step and
# obs-overhead rows), and the bench regression guard (decode-path ns/iter
# must stay within 20% of rust/BENCH_baseline.json and per-step copied
# bytes may never grow — in particular the paged-native decode step must
# stay at ZERO copied KV bytes).
#
# Usage: scripts/check.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== trace-emission smoke (exporter + validator) =="
TRACE_TMP="$(mktemp -d)"
ATTN_PIDS=()
cleanup() {
  for p in "${ATTN_PIDS[@]:-}"; do kill "$p" 2>/dev/null || true; done
  rm -rf "$TRACE_TMP"
}
trap cleanup EXIT
target/release/lamina trace-smoke --steps 6 --trace-out "$TRACE_TMP/trace.json"
python3 scripts/validate_trace.py "$TRACE_TMP/trace.json"
# a worker dying mid-session must still leave a well-formed (truncated) trace
target/release/lamina trace-smoke --steps 6 --kill-worker \
  --trace-out "$TRACE_TMP/trace-kill.json"
python3 scripts/validate_trace.py "$TRACE_TMP/trace-kill.json"

echo "== fault-matrix smoke (kill at prefill / mid-decode / drain x transport) =="
# artifact-free chaos sessions: each must recover bit-identically to its
# golden pass with zero leaked KV blocks (fault-smoke exits nonzero
# otherwise). Plans target the worker-link operation counts: the 1st
# send/recv is the membership handshake (Welcome/Hello), then ~6 sends
# during prefill, 4 per decode iteration, then the retire/drain tail.
for transport in inproc tcp; do
  for plan in "worker=0,kill-send=2" "worker=1,kill-send=21" "worker=0,kill-recv=18"; do
    echo "-- fault-smoke --transport $transport --fault-plan $plan"
    target/release/lamina fault-smoke --transport "$transport" --fault-plan "$plan"
  done
done
# no-recover mode: the death must surface typed, still with zero leaks
target/release/lamina fault-smoke --transport inproc \
  --fault-plan "worker=1,kill-send=21" --no-recover

echo "== membership smoke (degrade / adopt x transport) =="
# degrade: one of W=4 killed with respawn disabled — the pool reshards
# live to the 3 survivors, output stays bit-identical, zero leaks.
# adopt: W=2 -> 3 scale-up at a step boundary mid-session, also
# bit-identical (fault-smoke exits nonzero on any divergence or leak).
for transport in inproc tcp; do
  echo "-- fault-smoke --transport $transport --workers 4 --no-respawn (degrade)"
  target/release/lamina fault-smoke --transport "$transport" --workers 4 \
    --no-respawn --min-workers 2 --fault-plan "worker=1,kill-send=21"
  echo "-- fault-smoke --transport $transport --adopt 4 (scale-up)"
  target/release/lamina fault-smoke --transport "$transport" --adopt 4
done

echo "== multi-host smoke (lamina-attn subprocesses x {healthy, kill-one, degrade}) =="
# real cluster: standalone lamina-attn daemons on loopback ephemeral
# ports, leader dialing out with --workers ADDR,ADDR. Each scenario must
# stay bit-identical to its in-process golden pass with zero leaked KV
# blocks (fault-smoke exits nonzero otherwise).
start_attn() {  # start_attn OUTFILE — daemon in background, pid tracked
  target/release/lamina-attn --listen 127.0.0.1:0 >"$1" 2>/dev/null &
  ATTN_PIDS+=($!)
}
attn_addr() {  # attn_addr OUTFILE -> echoes the daemon's bound address
  for _ in $(seq 1 50); do
    grep -q "listening on" "$1" 2>/dev/null && break
    sleep 0.1
  done
  awk '/listening on/{print $NF}' "$1"
}
start_attn "$TRACE_TMP/attn1.addr"
start_attn "$TRACE_TMP/attn2.addr"
start_attn "$TRACE_TMP/attn3.addr"
A1="$(attn_addr "$TRACE_TMP/attn1.addr")"
A2="$(attn_addr "$TRACE_TMP/attn2.addr")"
A3="$(attn_addr "$TRACE_TMP/attn3.addr")"
echo "-- fault-smoke --workers $A1,$A2 (healthy remote pool)"
target/release/lamina fault-smoke --workers "$A1,$A2"
echo "-- fault-smoke --workers $A1,$A2 --fault-plan worker=1,kill-send=21 (kill-one, re-dial)"
# the sever drops the daemon's session; its accept loop serves the
# respawn re-dial of the SAME address as a fresh handshake
target/release/lamina fault-smoke --workers "$A1,$A2" \
  --fault-plan "worker=1,kill-send=21"
echo "-- fault-smoke --workers $A1,$A2,$A3 --no-respawn (degrade 3 -> 2)"
target/release/lamina fault-smoke --workers "$A1,$A2,$A3" \
  --no-respawn --min-workers 2 --fault-plan "worker=1,kill-send=21"

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== cargo bench (LAMINA_BENCH_QUICK=1) =="
  LAMINA_BENCH_QUICK=1 cargo bench
  echo "bench output: rust/BENCH_decode.json"

  echo "== bench regression guard =="
  python3 scripts/bench_guard.py rust/BENCH_baseline.json rust/BENCH_decode.json
fi

echo "check.sh: all green"
