#!/usr/bin/env bash
# Repo-wide verification gate: release build, full test suite, and the
# bench suite in quick mode (which also regenerates rust/BENCH_decode.json
# with codec GB/s, TCP-loopback RTT and KV-gather rows).
#
# Usage: scripts/check.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== cargo bench (LAMINA_BENCH_QUICK=1) =="
  LAMINA_BENCH_QUICK=1 cargo bench
  echo "bench output: rust/BENCH_decode.json"
fi

echo "check.sh: all green"
