#!/usr/bin/env bash
# Repo-wide verification gate: release build, full test suite, the obs
# trace-emission smoke (an artifact-free scripted session must export a
# Perfetto-parseable trace — happy path AND worker-death truncation), the
# fault-matrix smoke (chaos sessions with worker kills at prefill,
# mid-decode, and the drain tail, over both transports — each must recover
# bit-identically with zero leaked KV blocks), the bench suite in quick mode (which regenerates rust/BENCH_decode.json with
# codec GB/s, TCP-loopback RTT, KV-gather, native-kernel decode-step and
# obs-overhead rows), and the bench regression guard (decode-path ns/iter
# must stay within 20% of rust/BENCH_baseline.json and per-step copied
# bytes may never grow — in particular the paged-native decode step must
# stay at ZERO copied KV bytes).
#
# Usage: scripts/check.sh [--no-bench]
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== trace-emission smoke (exporter + validator) =="
TRACE_TMP="$(mktemp -d)"
trap 'rm -rf "$TRACE_TMP"' EXIT
target/release/lamina trace-smoke --steps 6 --trace-out "$TRACE_TMP/trace.json"
python3 scripts/validate_trace.py "$TRACE_TMP/trace.json"
# a worker dying mid-session must still leave a well-formed (truncated) trace
target/release/lamina trace-smoke --steps 6 --kill-worker \
  --trace-out "$TRACE_TMP/trace-kill.json"
python3 scripts/validate_trace.py "$TRACE_TMP/trace-kill.json"

echo "== fault-matrix smoke (kill at prefill / mid-decode / drain x transport) =="
# artifact-free chaos sessions: each must recover bit-identically to its
# golden pass with zero leaked KV blocks (fault-smoke exits nonzero
# otherwise). Plans target the worker-link operation counts: the 1st
# send/recv is the membership handshake (Welcome/Hello), then ~6 sends
# during prefill, 4 per decode iteration, then the retire/drain tail.
for transport in inproc tcp; do
  for plan in "worker=0,kill-send=2" "worker=1,kill-send=21" "worker=0,kill-recv=18"; do
    echo "-- fault-smoke --transport $transport --fault-plan $plan"
    target/release/lamina fault-smoke --transport "$transport" --fault-plan "$plan"
  done
done
# no-recover mode: the death must surface typed, still with zero leaks
target/release/lamina fault-smoke --transport inproc \
  --fault-plan "worker=1,kill-send=21" --no-recover

echo "== membership smoke (degrade / adopt x transport) =="
# degrade: one of W=4 killed with respawn disabled — the pool reshards
# live to the 3 survivors, output stays bit-identical, zero leaks.
# adopt: W=2 -> 3 scale-up at a step boundary mid-session, also
# bit-identical (fault-smoke exits nonzero on any divergence or leak).
for transport in inproc tcp; do
  echo "-- fault-smoke --transport $transport --workers 4 --no-respawn (degrade)"
  target/release/lamina fault-smoke --transport "$transport" --workers 4 \
    --no-respawn --min-workers 2 --fault-plan "worker=1,kill-send=21"
  echo "-- fault-smoke --transport $transport --adopt 4 (scale-up)"
  target/release/lamina fault-smoke --transport "$transport" --adopt 4
done

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== cargo bench (LAMINA_BENCH_QUICK=1) =="
  LAMINA_BENCH_QUICK=1 cargo bench
  echo "bench output: rust/BENCH_decode.json"

  echo "== bench regression guard =="
  python3 scripts/bench_guard.py rust/BENCH_baseline.json rust/BENCH_decode.json
fi

echo "check.sh: all green"
